"""IMPALA on continuous control with explicit policy-lag — reproduces the
survey's §6.1 claim: V-trace correction recovers performance lost to
actor/learner policy lag.

  PYTHONPATH=src python examples/impala_pendulum.py
"""
from repro.envs import CartPole
from repro.core.networks import MLPPolicy
from repro.launch.rl_train import run_impala


def main():
    env = CartPole()
    for lag in (0, 4):
        for vtrace in (True, False):
            pol = MLPPolicy(env.obs_dim, env.n_actions)
            _, hist = run_impala(env, pol, iters=60, n_envs=32,
                                 unroll=32, policy_lag=lag,
                                 use_vtrace=vtrace, seed=0, log_every=60)
            print(f"lag={lag} vtrace={vtrace}: "
                  f"return={hist[-1]['mean_episode_return']}")


if __name__ == "__main__":
    main()
