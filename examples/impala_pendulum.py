"""IMPALA with explicit policy-lag through the unified Trainer on the
registry-resolved CartPole (`envs.make("cartpole")`) — reproduces the
survey's §6.1 claim: V-trace correction recovers performance lost to
actor/learner policy lag.

  PYTHONPATH=src python examples/impala_pendulum.py
"""
import repro.envs as envs
from repro.core.trainer import Trainer, TrainerConfig


def main():
    env = envs.make("cartpole")
    for lag in (0, 4):
        for vtrace in (True, False):
            cfg = TrainerConfig(
                algo="impala", iters=60, superstep=10, n_envs=32,
                unroll=32, policy_lag=lag, seed=0, log_every=60,
                algo_kwargs={"use_vtrace": vtrace})
            _, hist = Trainer(env, cfg).fit()
            print(f"lag={lag} vtrace={vtrace}: "
                  f"return={hist[-1]['episode_return']}")


if __name__ == "__main__":
    main()
