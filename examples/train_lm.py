"""End-to-end LM training driver example: train a reduced assigned
architecture for a few hundred steps and verify the loss approaches the
synthetic stream's entropy floor.

  PYTHONPATH=src python examples/train_lm.py --arch smollm-360m \
      --steps 300

Any of the 10 assigned architectures works via --arch (see
`python -c "from repro.configs import list_archs; print(list_archs())"`).
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs real accelerators)")
    args = ap.parse_args()
    out = train(args.arch, reduced=not args.full, steps=args.steps,
                batch=args.batch, seq=args.seq, lr=args.lr,
                ckpt="experiments/ckpt_" + args.arch)
    print(f"params={out['n_params']:,} "
          f"final_ce={out['history'][-1]['ce']} "
          f"entropy_floor={out['optimal_ce']}")


if __name__ == "__main__":
    main()
