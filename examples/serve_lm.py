"""Batched serving example (the survey's Actor/inference path): prefill a
prompt batch, then decode with per-layer KV/recurrent caches — including
the sub-quadratic paths (rwkv6 state, gemma3 sliding-window ring cache).

  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b
"""
import argparse
import json

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()
    out = serve(args.arch, reduced=True, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
