"""Serve a trained policy from a checkpoint: the full production loop.

  PYTHONPATH=src python examples/serve_policy_cartpole.py

Train PPO on CartPole for a few iterations, save the TrainState with
repro.checkpoint, restore it into a fresh ParamStore
(`load_checkpoint` republishes the actor-policy view bitwise), then
replay a small open-loop offered load through the bucketed
micro-batching engine and report p50/p99 latency — with a live
hot-swap halfway through to show the compile counter staying flat.

For the real benchmark grid (multiple offered loads x bucket
configurations -> BENCH_serve.json) use the launcher:

  PYTHONPATH=src python -m repro.launch.serve_policy --algo ppo --quick
"""
import os
import tempfile

import jax
import numpy as np

import repro.envs as envs
from repro.checkpoint import save_checkpoint
from repro.core.serving import ParamStore, ServeEngine
from repro.core.trainer import Trainer, TrainerConfig
from repro.launch.serve_policy import run_offered_load

# ---- train + checkpoint ----------------------------------------------------
env = envs.make("cartpole")
cfg = TrainerConfig(algo="ppo", iters=12, superstep=4, n_envs=8,
                    unroll=16, seed=0, log_every=4)
trainer = Trainer(env, cfg)
state, hist = trainer.fit()
path = save_checkpoint(
    os.path.join(tempfile.mkdtemp(), "ppo_cartpole.npz"), state)
print("trained:", hist[-1], "->", path)

# ---- restore into a serving ParamStore -------------------------------------
store = ParamStore()
store.load_checkpoint(path, trainer.agent)
engine = ServeEngine.for_agent(trainer.agent, env, buckets=(4, 16),
                               store=store, seed=7)
print("warmup compiles:", engine.warmup())   # one per bucket

# ---- a mini offered-load replay (400 requests/second) ----------------------
obs_rows = np.asarray(jax.vmap(env.spec.observation.sample)(
    jax.random.split(jax.random.PRNGKey(1), 64)))
_, params = store.get()
swap = jax.tree_util.tree_map(lambda a: a * (1 + 1e-3), params)
cell = run_offered_load(engine, obs_rows, load_rps=400, n=200,
                        swap_params=swap)
print(f"served {cell['n']} requests @ {cell['offered_rps']:g} rps: "
      f"p50={cell['p50_ms']:.2f}ms p99={cell['p99_ms']:.2f}ms "
      f"throughput={cell['throughput_rps']:.0f} rps "
      f"versions_served={cell['versions']}")
print("engine stats:", engine.stats,
      "compiles:", engine.compile_count)   # still == warmup count
assert engine.compile_count == len(engine.buckets)
