"""Quickstart: the framework in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Train a reduced assigned-architecture LM for a few steps.
2. Serve it with a KV cache.
3. Run distributed DRL (IMPALA + V-trace) on the zero-copy CartPole,
   resolved through the env registry (`envs.make("cartpole")`) — then
   the same run pipelined: rollout producer and learner consumer
   decoupled by a device-resident trajectory queue.
4. Serve the trained policy: bucketed micro-batching + versioned
   zero-recompile hot-swap through repro.core.serving (see
   examples/serve_policy_cartpole.py for the checkpoint-restore and
   offered-load version).
5. Run an ES generation (evolution-based training, survey §7) with the
   policy built from the env's spec (`MLPPolicy.for_spec`).
"""
import jax
import jax.numpy as jnp

# ---- 1. LM training (learner path) ---------------------------------------
from repro.launch.train import train

out = train("gemma3-1b", reduced=True, steps=30, batch=8, seq=64,
            lr=1e-3, log_every=10)
print("train:", out["history"][-1], "optimal_ce:", out["optimal_ce"])

# ---- 2. Serving (actor path) ----------------------------------------------
from repro.launch.serve import serve

print("serve:", serve("gemma3-1b", reduced=True, batch=2,
                      prompt_len=16, gen_len=8))

# ---- 3. Distributed DRL: IMPALA through the unified Trainer ----------------
import repro.envs as envs
from repro.core.distribution import DistPlan
from repro.core.trainer import Trainer, TrainerConfig

env = envs.make("cartpole")          # name registry, parallel to agent.make
# The distribution is declared, not hard-coded: a DistPlan names the
# mesh axes (1-D here; try DistPlan.grid(2, 2) on 4 devices), the
# per-axis collective + sync discipline, and an elastic actor-shard
# schedule — env shards cycle 16 -> 32 between supersteps while the
# agent only ever sees `traj`.
plan = DistPlan.flat(1, collective="allreduce", sync="bsp",
                     actors=(16, 32))
cfg = TrainerConfig(algo="impala", iters=40, superstep=10, n_envs=16,
                    unroll=16, plan=plan, policy_lag=2, log_every=10)
trainer = Trainer(env, cfg)
state, hist = trainer.fit()
print("impala:", hist[-1], "plan:", plan.describe(),
      "actor_shards:", trainer.actor_shards)

# ---- 3b. The same run, pipelined ------------------------------------------
# pipeline=True decouples each iteration into a rollout producer and a
# learner consumer joined by a device-resident trajectory queue
# (repro.core.pipeline). The queue depth is whatever staleness the
# plan's sync discipline admits: this ssp plan allows the producer to
# run 1 iteration ahead of the learner; a bsp plan would pin depth 0
# (lockstep — bitwise identical to the fused run above).
pplan = DistPlan.flat(1, collective="allreduce", sync="ssp",
                      staleness_bound=1, max_delay=1)
pcfg = TrainerConfig(algo="impala", iters=40, superstep=10, n_envs=16,
                     unroll=16, plan=pplan, log_every=10, pipeline=True)
ptrainer = Trainer(env, pcfg)
_, phist = ptrainer.fit()
print("impala/pipelined:", phist[-1],
      f"depth={ptrainer.pipeline_depth}",
      f"queue_capacity={ptrainer.pipeline_capacity}")

# ---- 4. Serve the trained policy ------------------------------------------
# The traffic-facing mirror of the Trainer: publish the live
# actor-policy view into a versioned ParamStore, warm up one compiled
# program per bucket size, and serve micro-batches padded to the
# smallest fitting bucket. Hot-swapping fresh params is zero-recompile
# by construction (params are traced inputs), pinned by compile_count.
from repro.core.serving import ServeEngine

engine = ServeEngine.for_agent(trainer.agent, env, buckets=(1, 4, 16))
engine.store.publish_from_state(trainer.agent, state)
engine.warmup()                      # one compile per bucket, up front
obs = jax.vmap(env.spec.observation.sample)(
    jax.random.split(jax.random.PRNGKey(2), 7))
actions = engine.serve(obs)          # 7 requests -> buckets 16 (or 4+4...)
c0 = engine.compile_count
engine.store.publish_from_state(trainer.agent, state)   # hot-swap
engine.serve(obs)
print("serve_policy:", f"actions={actions.tolist()}",
      f"version={engine.store.version}",
      f"compiles={engine.compile_count} (was {c0} before hot-swap)",
      f"stats={engine.stats}")
assert engine.compile_count == c0    # the zero-recompile pin, live

# ---- 5. Evolution strategies (survey §7) -----------------------------------
from repro.core.networks import MLPPolicy
from repro.core.evo import ES

penv = envs.make("pendulum")
ppol = MLPPolicy.for_spec(penv.spec, hidden=(16,))
es = ES(ppol, penv, pop_size=16, max_steps=100)
theta = es.init(jax.random.PRNGKey(0))
theta, fitness, comm = jax.jit(es.step)(theta, jax.random.PRNGKey(1))
print(f"es: mean_fitness={float(fitness):.1f} comm_bytes={comm} "
      f"(vs {4 * theta.size} for a gradient exchange)")
