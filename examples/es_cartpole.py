"""Evolution-based training (survey §7): ES and Deep-GA on the
registry-resolved CartPole (`envs.make("cartpole")`), reporting the
per-generation communication bytes that make evolutionary methods
massively parallelizable.

  PYTHONPATH=src python examples/es_cartpole.py
"""
import jax

import repro.envs as envs
from repro.core.networks import MLPPolicy
from repro.core.evo import ES, DeepGA


def main():
    env = envs.make("cartpole")
    pol = MLPPolicy.for_spec(env.spec, hidden=(16,))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        pol.init(jax.random.PRNGKey(0))))

    es = ES(pol, env, pop_size=32, sigma=0.3, lr=0.2, max_steps=200)
    theta = es.init(jax.random.PRNGKey(0))
    step = jax.jit(es.step)
    for g in range(10):
        theta, fit, comm = step(theta, jax.random.fold_in(
            jax.random.PRNGKey(1), g))
        print(f"ES gen {g}: mean_fitness={float(fit):.1f} "
              f"comm={comm}B (grad exchange would be {4 * n_params}B)")

    ga = DeepGA(pol, env, pop_size=32, truncation=8, sigma=0.3,
                max_steps=200)
    state = ga.init(jax.random.PRNGKey(0))
    gstep = jax.jit(ga.step)
    for g in range(10):
        state, best, comm = gstep(state, jax.random.fold_in(
            jax.random.PRNGKey(2), g))
        print(f"GA gen {g}: best_fitness={float(best):.1f} comm={comm}B "
              f"(seed-chain encoding)")


if __name__ == "__main__":
    main()
