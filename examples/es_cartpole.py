"""Evolution-based training (survey §7): ES and Deep-GA on the
registry-resolved CartPole (`envs.make("cartpole")`), reporting the
per-generation communication bytes that make evolutionary methods
massively parallelizable — then a gradient-based baseline driven by the
unified Trainer under an explicit `DistPlan` (declared mesh, collective,
sync and elastic actor shards) for the comparison.

  PYTHONPATH=src python examples/es_cartpole.py
"""
import jax

import repro.envs as envs
from repro.core.distribution import DistPlan
from repro.core.networks import MLPPolicy
from repro.core.evo import ES, DeepGA
from repro.core.trainer import Trainer, TrainerConfig


def main():
    env = envs.make("cartpole")
    pol = MLPPolicy.for_spec(env.spec, hidden=(16,))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        pol.init(jax.random.PRNGKey(0))))

    es = ES(pol, env, pop_size=32, sigma=0.3, lr=0.2, max_steps=200)
    theta = es.init(jax.random.PRNGKey(0))
    step = jax.jit(es.step)
    for g in range(10):
        theta, fit, comm = step(theta, jax.random.fold_in(
            jax.random.PRNGKey(1), g))
        print(f"ES gen {g}: mean_fitness={float(fit):.1f} "
              f"comm={comm}B (grad exchange would be {4 * n_params}B)")

    ga = DeepGA(pol, env, pop_size=32, truncation=8, sigma=0.3,
                max_steps=200)
    state = ga.init(jax.random.PRNGKey(0))
    gstep = jax.jit(ga.step)
    for g in range(10):
        state, best, comm = gstep(state, jax.random.fold_in(
            jax.random.PRNGKey(2), g))
        print(f"GA gen {g}: best_fitness={float(best):.1f} comm={comm}B "
              f"(seed-chain encoding)")

    # gradient-based baseline under an explicit DistPlan: the 1-D mesh,
    # collective and sync are declared (not hard-coded flags), and the
    # elastic actors= schedule cycles the env-shard count 16 -> 32
    # between supersteps — gradient exchange moves 4*n_params bytes per
    # step where ES moved `comm`
    plan = DistPlan.flat(1, collective="allreduce", sync="bsp",
                         actors=(16, 32))
    cfg = TrainerConfig(algo="a3c", iters=20, superstep=5, n_envs=16,
                        unroll=32, plan=plan, log_every=10)
    trainer = Trainer(env, cfg)
    _, hist = trainer.fit()
    print(f"A3C baseline under plan {plan.describe()}: "
          f"{hist[-1]} actor_shards={trainer.actor_shards} "
          f"(grad exchange: {4 * n_params}B/step)")


if __name__ == "__main__":
    main()
