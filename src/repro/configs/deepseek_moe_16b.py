"""DeepSeek-MoE-16B [arXiv:2401.06066] — fine-grained experts.

28L d_model=2048 16H (kv=16) vocab=102400. Layer 0 is a dense FFN
(d_ff=10944); layers 1..27 are MoE with 64 routed experts (top-6,
expert d_ff=1408 per the assignment) + 2 shared experts.
"""
from repro.configs.base import ModelConfig, MoESpec, ATTN, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab=102400, layer_pattern=(ATTN,), norm="rmsnorm",
    moe=MoESpec(n_experts=64, top_k=6, d_ff=1408, n_shared=2, every=1,
                first_dense=1),
    source="arXiv:2401.06066",
))
