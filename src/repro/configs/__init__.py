"""Architecture registry. `load_all()` imports every per-arch module."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoESpec, ShapeConfig, SHAPES, TRAIN_4K, PREFILL_32K,
    DECODE_32K, LONG_500K, get_config, list_archs, register,
    ATTN, ATTN_LOCAL, MLA, RWKV, MAMBA,
)

_ARCH_MODULES = (
    "stablelm_1_6b", "smollm_360m", "gemma3_1b", "minicpm3_4b", "rwkv6_1_6b",
    "whisper_base", "llama4_maverick_400b_a17b", "deepseek_moe_16b",
    "jamba_v0_1_52b", "paligemma_3b", "paper_drl",
)

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
