"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-135M family] — llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152. Tied embeddings,
RMSNorm, SwiGLU.
"""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152, layer_pattern=(ATTN,), norm="rmsnorm",
    tie_embeddings=True, rope_theta=10000.0,
    source="hf:HuggingFaceTB/SmolLM-135M",
))
