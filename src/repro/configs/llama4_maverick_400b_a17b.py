"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; MoE 128 experts
top-1 with one shared expert, MoE FFN on alternating layers (dense FFN on
the others) — matching Maverick's interleaved dense/MoE design. "Early
fusion" multimodality is out of scope of the language backbone (text
configs only, per the assigned-architecture carve-out).
"""
from repro.configs.base import ModelConfig, MoESpec, ATTN, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, layer_pattern=(ATTN,), norm="rmsnorm",
    rope_theta=500000.0,
    moe=MoESpec(n_experts=128, top_k=1, d_ff=8192, n_shared=1, every=2),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
