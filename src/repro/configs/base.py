"""Config system: model/shape configs and the architecture registry.

Every assigned architecture is a `ModelConfig`; the four assigned input
shapes are `ShapeConfig`s. Configs are plain frozen dataclasses so they
hash/compare and can be used as static args under `jax.jit`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds making up a layer pattern. A model is a repeated "super-block"
# pattern of these, which lets heterogeneous stacks (gemma3 5:1 local:global,
# jamba 1 attn : 7 mamba) lower as scans over homogeneous groups.
# ---------------------------------------------------------------------------
ATTN = "attn"            # full (global) softmax attention
ATTN_LOCAL = "attn_local"  # sliding-window attention
MLA = "mla"              # multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
RWKV = "rwkv6"           # RWKV-6 "Finch" token-mix block (attention-free)
MAMBA = "mamba"          # Mamba selective-SSM block

SUBQUADRATIC = frozenset({ATTN_LOCAL, RWKV, MAMBA})


@dataclasses.dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts FFN spec."""
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    n_shared: int = 0              # always-on shared experts (DeepSeek-MoE)
    every: int = 1                 # MoE FFN every `every` layers (llama4 alternates)
    first_dense: int = 0           # leading dense layers (DeepSeek-MoE layer 0)
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    layer_pattern: Tuple[str, ...] = (ATTN,)   # repeated to cover n_layers
    window: int = 0                # sliding window size for ATTN_LOCAL
    moe: Optional[MoESpec] = None
    # MLA (only when MLA in pattern)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 64        # decoupled-rope dims for MLA
    # SSM
    ssm_state: int = 16            # mamba state dim per channel
    ssm_conv: int = 4
    ssm_expand: int = 2
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_tokens: int = 0            # encoder sequence length (stub frontend output)
    # multimodal frontend stub
    frontend: str = "none"         # none | audio_stub | vision_stub
    frontend_tokens: int = 0       # prepended embedding tokens (vlm)
    frontend_dim: int = 0          # stub embedding dim (0 -> d_model)
    # misc
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    source: str = ""               # citation

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    def pattern(self) -> Tuple[str, ...]:
        """Full per-layer block-kind list of length n_layers."""
        reps = math.ceil(self.n_layers / len(self.layer_pattern))
        return tuple((self.layer_pattern * reps)[: self.n_layers])

    def is_moe_layer(self, i: int) -> bool:
        m = self.moe
        if m is None:
            return False
        if i < m.first_dense:
            return False
        return (i - m.first_dense) % m.every == 0

    def subquadratic(self) -> bool:
        """True if decode at very long context is feasible (no full-attn
        layer whose KV cache must span the whole context... full attention
        layers are allowed only if every layer kind is sub-quadratic OR the
        arch is hybrid/ssm/local-windowed)."""
        kinds = set(self.pattern())
        full = {ATTN, MLA} & kinds
        if not full:
            return True
        # hybrid archs with a minority of full-attn layers still run 500k
        # (cache shards over the data axis); pure full-attn archs do not.
        n_full = sum(1 for k in self.pattern() if k in (ATTN, MLA))
        return n_full <= self.n_layers // 4

    # -- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.head_dim
        total = self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d
        for i, kind in enumerate(self.pattern()):
            # token mixer
            if kind == ATTN or kind == ATTN_LOCAL:
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
            elif kind == MLA:
                rq = self.q_lora_rank or d
                total += d * rq + rq * self.n_heads * (hd + self.rope_head_dim)
                total += d * (self.kv_lora_rank + self.rope_head_dim)
                total += self.kv_lora_rank * self.n_heads * 2 * hd
                total += self.n_heads * hd * d
            elif kind == RWKV:
                # r,k,v,g,o projections + decay/low-rank mixers (approx)
                total += 5 * d * d + 4 * d * 64
            elif kind == MAMBA:
                di = self.ssm_expand * d
                total += d * 2 * di + di * d        # in_proj, out_proj
                total += di * self.ssm_conv          # conv
                total += di * (2 * self.ssm_state)   # B,C proj (x-dependent)
                total += di * 2                      # dt proj (rank-1 approx) + A,D
            # channel mixer (FFN) — every block has one except RWKV's
            # built-in channel-mix
            if kind in (RWKV,):
                total += 2 * d * int(self.d_ff) + d * d  # k,v + receptance
            elif self.is_moe_layer(i):
                m = self.moe
                e = (m.top_k if active_only else m.n_experts) + m.n_shared
                total += e * 3 * d * m.d_ff + d * m.n_experts  # router
            else:
                total += 3 * d * self.d_ff  # swiglu
        # encoder (whisper): same-dim encoder layers, full attn + mlp
        for _ in range(self.enc_layers):
            total += 4 * d * d + 3 * d * self.d_ff
        return int(total)

    # -- reduced variant for CPU smoke tests ----------------------------
    def reduced(self) -> "ModelConfig":
        d = min(self.d_model, 128)
        n_heads = max(2, min(self.n_heads, 4))
        hd = max(8, d // n_heads)
        kv = 1 if self.n_kv_heads == 1 else max(1, min(self.n_kv_heads, 2))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff=64, n_shared=min(self.moe.n_shared, 1),
                first_dense=min(self.moe.first_dense, 1))
        # keep at least one full super-block of the pattern
        n_layers = max(2, len(self.layer_pattern))
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=d, n_heads=n_heads,
            n_kv_heads=kv, head_dim=hd, d_ff=128, vocab=512, moe=moe,
            q_lora_rank=min(self.q_lora_rank, 32) if self.q_lora_rank else 0,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.kv_lora_rank else 0,
            rope_head_dim=min(self.rope_head_dim, 16),
            window=min(self.window, 64) if self.window else 0,
            enc_layers=min(self.enc_layers, 2),
            enc_tokens=min(self.enc_tokens, 32) if self.enc_tokens else 0,
            frontend_tokens=min(self.frontend_tokens, 16)
            if self.frontend_tokens else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import the per-arch modules lazily on first miss
        from repro import configs as _c  # noqa
        _c.load_all()
    return _REGISTRY[name]


def list_archs():
    from repro import configs as _c
    _c.load_all()
    return sorted(_REGISTRY)
