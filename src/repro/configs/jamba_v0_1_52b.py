"""Jamba-v0.1 52B [arXiv:2403.19887] — Mamba+attention 1:7 interleave, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; MoE 16 experts
top-2 on every other layer. Each 8-layer super-block has one attention
layer and seven Mamba layers. Hybrid: long_500k runs (attention cache on
only 4 of 32 layers, sharded over the data axis; Mamba state is O(1)).
"""
from repro.configs.base import ModelConfig, MoESpec, ATTN, MAMBA, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=65536, head_dim=128,
    layer_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    ssm_state=16, ssm_conv=4, ssm_expand=2, norm="rmsnorm",
    moe=MoESpec(n_experts=16, top_k=2, d_ff=14336, n_shared=0, every=2),
    source="arXiv:2403.19887",
))
