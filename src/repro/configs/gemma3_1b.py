"""Gemma-3-1B [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256,
5:1 local:global attention interleave (window=512), 128k-class context.
Sub-quadratic long-context decode is possible because only every 6th
layer is global (cache for global layers shards over the data axis).
"""
from repro.configs.base import ModelConfig, ATTN, ATTN_LOCAL, register

CONFIG = register(ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262144, head_dim=256,
    layer_pattern=(ATTN_LOCAL,) * 5 + (ATTN,), window=512,
    norm="rmsnorm", tie_embeddings=True, rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt",
))
