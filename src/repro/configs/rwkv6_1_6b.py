"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892] — attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536. 32 heads of size 64 for the WKV
state. O(1)-state decode: long_500k runs.
"""
from repro.configs.base import ModelConfig, RWKV, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab=65536, head_dim=64, layer_pattern=(RWKV,), norm="layernorm",
    source="arXiv:2404.05892",
))
