"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — MLA (multi-head latent attention).

62L d_model=2560 40H d_ff=6400 vocab=73448. MLA with q_lora_rank=768,
kv_lora_rank=256, qk_nope_head_dim=64 (head_dim), qk_rope_head_dim=32.
MLA's compressed KV latent (256 + 32 per token) is what makes its decode
cache small, but attention over the context is still full — long_500k is
skipped per the pure-full-attention rule.
"""
from repro.configs.base import ModelConfig, MLA, register

CONFIG = register(ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, head_dim=64,
    layer_pattern=(MLA,), q_lora_rank=768, kv_lora_rank=256,
    rope_head_dim=32, norm="rmsnorm", rope_theta=10000.0,
    source="hf:openbmb/MiniCPM3-4B",
))
