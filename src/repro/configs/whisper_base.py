"""Whisper-base [arXiv:2212.04356] — encoder-decoder, conv frontend STUB.

6L encoder + 6L decoder, d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
The mel-spectrogram + conv feature extractor is a stub: input_specs()
provides 1500 precomputed frame embeddings of shape (B, 1500, 512); we
implement the transformer encoder over them and the text decoder with
self- + cross-attention. Decode shapes exercise the decoder with KV
cache; long_500k skipped (full attention).
"""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, layer_pattern=(ATTN,), norm="layernorm",
    enc_layers=6, enc_tokens=1500, frontend="audio_stub",
    source="arXiv:2212.04356",
))
