"""The survey's own workload: a small policy trunk for the DRL engine.

Used by examples/impala_pendulum.py etc. as the policy/value backbone when
a transformer trunk (rather than an MLP) is requested — ties the assigned
model zoo to the paper's distributed-DRL machinery.
"""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="paper-drl-trunk", family="dense",
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab=1024, layer_pattern=(ATTN,), norm="rmsnorm",
    source="survey §3 actor/learner policy backbone",
))
