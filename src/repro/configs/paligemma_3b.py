"""PaliGemma-3B [arXiv:2407.07726] — SigLIP + Gemma; vision frontend STUB.

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216, head_dim=256.
The SigLIP vision tower + projector is a stub: input_specs() provides 256
precomputed patch embeddings (B, 256, 2048) prepended to the text tokens.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=257216, head_dim=256, layer_pattern=(ATTN,), norm="rmsnorm",
    tie_embeddings=True, frontend="vision_stub", frontend_tokens=256,
    frontend_dim=1152,  # SigLIP width; learned projector maps to d_model
    rope_theta=10000.0,
    source="arXiv:2407.07726",
))
