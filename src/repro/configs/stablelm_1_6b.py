"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32, i.e. MHA) d_ff=5632 vocab=100352.
Dense decoder, LayerNorm, rotary on 25% of head dim (we apply full rope —
noted simplification), untied embeddings.
"""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100352, layer_pattern=(ATTN,), norm="layernorm",
    rope_theta=10000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
))
