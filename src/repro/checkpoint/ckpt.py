"""Checkpointing: flatten the (params, opt_state, step) pytree to a
key-path -> array npz archive. Sharding-aware on restore: arrays are
device_put against the target sharding (on a real mesh each host only
materializes its addressable shards)."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(path, tree, step=None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    np.savez(path, **flat)
    return path


def load_train_state(path, agent, example=None, key=None):
    """Restore a Trainer-produced TrainState archive for an Agent —
    the checkpoint half of the serving hot-swap path
    (repro.core.serving.ParamStore.load_checkpoint). `example` defaults
    to `agent.init(PRNGKey(0))`, so the agent must be constructed with
    the same config (ring_size, replay capacity, ...) that produced the
    checkpoint; pass an explicit example TrainState otherwise. Returns
    `(state, step)`."""
    if example is None:
        example = agent.init(jax.random.PRNGKey(0) if key is None
                             else key)
    # a ZeRO-3 agent (topology.ZeRO3Agent) inits in its sharded wrapper
    # form; checkpoints are written in the reassembled (plan-independent)
    # tree shape `fit` returns, so reassemble the template to match
    example = getattr(agent, "host_state", lambda s: s)(example)
    return load_checkpoint(path, example)


def load_checkpoint(path, example_tree, shardings=None):
    """Restore into the structure of `example_tree`. `shardings` (same
    structure, optional) device_puts each leaf against its sharding."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        data = {k: z[k] for k in z.files}
    step = data.pop("__step__", None)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    out = []
    for path_keys, leaf in leaves_p:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        arr = data[key].astype(leaf.dtype) if hasattr(leaf, "dtype") \
            else data[key]
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, (int(step) if step is not None else None)
