from repro.checkpoint.ckpt import (save_checkpoint,  # noqa: F401
                                   load_checkpoint, load_train_state)
