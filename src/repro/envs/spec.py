"""EnvSpec: typed observation/action spaces for the env substrate.

The spec is the env-side mirror of the agent seam (repro.core.agent):
everything that used to be read off `obs_dim`/`n_actions`/`act_dim`
class attributes — policy construction, rollout action scaling, DQN
replay templates — is derived from one immutable `EnvSpec` instead, so
new envs (and wrapped/scenario variants) carry their own contract.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Space:
    """A (possibly bounded) array space.

    `n > 0` marks a discrete space with `n` categories (shape is then the
    shape of the integer action array, usually `()`); `n == 0` marks a
    continuous box with `low`/`high` bounds (None = unbounded).
    """
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    low: float = None
    high: float = None
    n: int = 0

    @property
    def discrete(self) -> bool:
        return self.n > 0

    @property
    def size(self) -> int:
        """Number of scalar entries (flattened width)."""
        return int(math.prod(self.shape)) if self.shape else 1

    # -- bounds helpers (continuous only) ------------------------------
    @property
    def midpoint(self) -> float:
        lo = -1.0 if self.low is None else self.low
        hi = 1.0 if self.high is None else self.high
        return 0.5 * (lo + hi)

    @property
    def half_range(self) -> float:
        lo = -1.0 if self.low is None else self.low
        hi = 1.0 if self.high is None else self.high
        return 0.5 * (hi - lo)

    def sample(self, key):
        """A uniform random element (conformance tests / exploration)."""
        if self.discrete:
            return jax.random.randint(key, self.shape, 0, self.n)
        lo = -1.0 if self.low is None else self.low
        hi = 1.0 if self.high is None else self.high
        return jax.random.uniform(key, self.shape, self.dtype, lo, hi)

    def contains(self, x) -> bool:
        """Host-side containment check (conformance tests)."""
        x = jnp.asarray(x)
        if x.shape[-len(self.shape):] != self.shape and self.shape:
            return False
        if self.discrete:
            return bool(jnp.all((x >= 0) & (x < self.n)))
        ok = jnp.isfinite(x)
        if self.low is not None:
            ok = ok & (x >= self.low - 1e-5)
        if self.high is not None:
            ok = ok & (x <= self.high + 1e-5)
        return bool(jnp.all(ok))


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """The immutable contract between an environment and its consumers.

    `episode_len` is the env's internal step cap (0 = none); wrappers
    like TimeLimit publish a tightened spec.
    """
    name: str
    observation: Space
    action: Space
    episode_len: int = 0

    # -- the attributes the seed API exposed, derived ------------------
    @property
    def obs_dim(self) -> int:
        return self.observation.size

    @property
    def n_actions(self) -> int:
        return self.action.n

    @property
    def act_dim(self) -> int:
        return 1 if self.action.discrete else self.action.size

    def replace(self, **kw) -> "EnvSpec":
        return dataclasses.replace(self, **kw)


def discrete(n: int, shape: Tuple[int, ...] = ()) -> Space:
    """Discrete action/observation space with `n` categories."""
    return Space(shape=shape, dtype=jnp.int32, n=n)


def box(shape, low=None, high=None, dtype=jnp.float32) -> Space:
    """Continuous box space."""
    return Space(shape=tuple(shape), dtype=dtype, low=low, high=high)
