"""N×N gridworld with a fixed goal (discrete, 4 actions) — the
token-friendly env used to drive transformer-trunk policies."""
import jax
import jax.numpy as jnp

from repro.envs.api import Env


class GridWorld(Env):
    n_actions = 4

    def __init__(self, n=8, max_steps=64):
        self.n = n
        self.max_steps = max_steps
        self.obs_dim = 4  # (x, y, gx, gy) normalized
        self.goal = jnp.array([n - 1, n - 1])

    def reset(self, key):
        pos = jax.random.randint(key, (2,), 0, self.n)
        return {"pos": pos, "t": jnp.zeros((), jnp.int32)}

    def obs(self, state):
        return jnp.concatenate([state["pos"], self.goal]
                               ).astype(jnp.float32) / self.n

    def step(self, state, action):
        delta = jnp.array([[0, 1], [0, -1], [1, 0], [-1, 0]])[action]
        pos = jnp.clip(state["pos"] + delta, 0, self.n - 1)
        t = state["t"] + 1
        at_goal = jnp.all(pos == self.goal)
        reward = jnp.where(at_goal, 1.0, -0.01)
        done = at_goal | (t >= self.max_steps)
        s = {"pos": pos, "t": t}
        return s, self.obs(s), reward, done

    def token_obs(self, state):
        """Integer token encoding (for transformer-trunk policies)."""
        return state["pos"][0] * self.n + state["pos"][1]
