"""N×N gridworld (discrete, 4 actions) — the token-friendly env used to
drive transformer-trunk policies.

Layout (grid size and goal placement) lives in the scenario pytree, so
a batch of envs can mix sizes and goals inside one `vmap`'d rollout;
`gridworld-rand` re-draws both per episode.
"""
import jax
import jax.numpy as jnp

from repro.envs.api import Env
from repro.envs.registry import register
from repro.envs.spec import EnvSpec, box, discrete


class GridWorld(Env):
    def __init__(self, n=8, max_steps=64, random_goal=False,
                 scenario=None, ranges=None):
        self.n = n
        self.max_steps = max_steps
        self.random_goal = random_goal
        super().__init__(scenario, ranges)

    @property
    def spec(self):
        return EnvSpec("gridworld",
                       observation=box((4,), low=0.0, high=1.0),
                       action=discrete(4),
                       episode_len=self.max_steps)

    def default_scenario(self):
        return {"n": jnp.int32(self.n),
                "goal": jnp.array([self.n - 1, self.n - 1], jnp.int32)}

    def sample_scenario(self, key):
        scn = super().sample_scenario(key)
        if self.random_goal:
            scn["goal"] = jax.random.randint(
                jax.random.fold_in(key, 101), (2,), 0, scn["n"], jnp.int32)
        # keep the goal reachable when "n" is randomized/overridden
        # below the default layout's grid size
        scn["goal"] = jnp.minimum(scn["goal"], scn["n"] - 1)
        return scn

    def reset_scenario(self, key, scn):
        pos = jax.random.randint(key, (2,), 0, scn["n"])
        return {"pos": pos, "t": jnp.zeros((), jnp.int32)}

    def obs(self, state):
        scn = state["scn"]
        return (jnp.concatenate([state["pos"], scn["goal"]])
                .astype(jnp.float32) / scn["n"])

    def step(self, state, action):
        scn = state["scn"]
        delta = jnp.array([[0, 1], [0, -1], [1, 0], [-1, 0]])[action]
        pos = jnp.clip(state["pos"] + delta, 0, scn["n"] - 1)
        t = state["t"] + 1
        at_goal = jnp.all(pos == scn["goal"])
        reward = jnp.where(at_goal, 1.0, -0.01)
        done = at_goal | (t >= self.max_steps)
        s = {"pos": pos, "t": t, "scn": scn}
        return s, self.obs(s), reward, done

    def token_obs(self, state):
        """Integer token encoding (for transformer-trunk policies)."""
        return state["pos"][0] * state["scn"]["n"] + state["pos"][1]


register("gridworld", GridWorld)
register("gridworld-rand",
         lambda n=8, ranges=None, **kw: GridWorld(
             n=n, random_goal=True,
             ranges=dict({"n": (4, n)}, **(ranges or {})), **kw))
