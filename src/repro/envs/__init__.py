"""Environment substrate: spec'd, registered, wrapped, scenario-batched.

  env = envs.make("cartpole-rand")          # name registry
  env.spec                                  # typed obs/action spaces
  envs.register("my-env", MyEnv)            # 3rd-party registration

See repro.envs.api for the Env protocol, repro.envs.wrappers for the
pure-functional wrapper stack, and ROADMAP.md ("Extending the env
substrate") for how to add envs, wrappers and scenario families.
"""
from repro.envs.api import Env  # noqa: F401
from repro.envs.spec import EnvSpec, Space, box, discrete  # noqa: F401
from repro.envs.registry import available, make, register  # noqa: F401
from repro.envs.wrappers import (ActionRepeat, ObsNormalize,  # noqa: F401
                                 RewardScale, TimeLimit, Wrapper)
from repro.envs.cartpole import CartPole  # noqa: F401
from repro.envs.pendulum import Pendulum  # noqa: F401
from repro.envs.gridworld import GridWorld  # noqa: F401

# -- wrapped variants: prove the substrate carries composed workloads --
# (HostPipelined stays unregistered — it is a benchmark baseline, see
# repro.envs.host_env / benchmarks/fig5_simulation.py.)
register("pendulum-norm",
         lambda **kw: ObsNormalize(RewardScale(Pendulum(**kw), 0.1)))
register("cartpole-repeat",
         lambda repeat=2, max_steps=100, **kw: ActionRepeat(
             TimeLimit(CartPole(**kw), max_steps), repeat))
