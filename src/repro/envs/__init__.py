from repro.envs.api import Env  # noqa: F401
from repro.envs.cartpole import CartPole  # noqa: F401
from repro.envs.pendulum import Pendulum  # noqa: F401
from repro.envs.gridworld import GridWorld  # noqa: F401
