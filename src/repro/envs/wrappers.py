"""Pure-functional env wrappers (survey §4.2: the composable simulation
substrate).

Wrapper state lives *inside the env-state pytree* under `state["wrap"]`
(the wrapped env's state nests under `state["inner"]`), so a wrapped env
is still a pure `reset`/`step` over jnp pytrees — everything stays
jit/vmap/scan-fusable and rides through `shard_map` worker meshes
untouched. Wrappers compose by nesting.

`autoreset_merge` / `wrap_merge` control what survives an episode
boundary: TimeLimit's step counter resets with the episode, while
ObsNormalize's running mean/var deliberately persists (`wrap_merge`
keeps the stepped state), which is what makes per-env online obs
normalization work under batched autoreset.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.envs.api import Env
from repro.envs.spec import EnvSpec


class Wrapper(Env):
    """Base wrapper: state = {"inner": inner_state, "wrap": own_state}.

    Subclasses override any of `wrap_init` (own state from the inner
    reset state), `obs`, `step`, `wrap_merge` (autoreset persistence)
    and `spec`.
    """

    def __init__(self, inner: Env):
        self.inner = inner

    @property
    def spec(self) -> EnvSpec:
        return self.inner.spec

    # -- wrapper-state hooks -------------------------------------------
    def wrap_init(self, inner_state) -> dict:
        return {}

    def wrap_merge(self, fresh, new, sel):
        """Merge own state at episode boundaries (default: reset it)."""
        return jax.tree_util.tree_map(sel, fresh, new)

    # -- Env protocol --------------------------------------------------
    def reset(self, key):
        s = self.inner.reset(key)
        return {"inner": s, "wrap": self.wrap_init(s)}

    def obs(self, state):
        return self.inner.obs(state["inner"])

    def step(self, state, action):
        s, o, r, d = self.inner.step(state["inner"], action)
        return {"inner": s, "wrap": state["wrap"]}, o, r, d

    def autoreset_merge(self, fresh, new_state, sel):
        return {"inner": self.inner.autoreset_merge(
                    fresh["inner"], new_state["inner"], sel),
                "wrap": self.wrap_merge(fresh["wrap"], new_state["wrap"],
                                        sel)}


class TimeLimit(Wrapper):
    """Truncate episodes at `max_steps` (own counter — works on any env,
    including ones whose internal cap is longer or absent)."""

    def __init__(self, inner: Env, max_steps: int):
        super().__init__(inner)
        self.max_steps = max_steps

    @property
    def spec(self):
        inner = self.inner.spec
        cap = (min(inner.episode_len, self.max_steps)
               if inner.episode_len else self.max_steps)
        return inner.replace(episode_len=cap)

    def wrap_init(self, inner_state):
        return {"t": jnp.zeros((), jnp.int32)}

    def step(self, state, action):
        s, o, r, d = self.inner.step(state["inner"], action)
        t = state["wrap"]["t"] + 1
        return {"inner": s, "wrap": {"t": t}}, o, r, d | (t >=
                                                          self.max_steps)


class ObsNormalize(Wrapper):
    """Online per-env observation normalization (Welford running
    mean/var carried in wrapper state; persists across autoresets)."""

    def __init__(self, inner: Env, eps: float = 1e-4, clip: float = 10.0):
        super().__init__(inner)
        self.eps = eps
        self.clip = clip

    @property
    def spec(self):
        inner = self.inner.spec
        return inner.replace(observation=dataclasses.replace(
            inner.observation, low=-self.clip, high=self.clip))

    def wrap_init(self, inner_state):
        o0 = self.inner.obs(inner_state)
        return {"count": jnp.ones((), jnp.float32),
                "mean": o0.astype(jnp.float32),
                "m2": jnp.zeros_like(o0, jnp.float32)}

    def wrap_merge(self, fresh, new, sel):
        return new  # running statistics survive episode boundaries

    def _normalize(self, stats, o):
        var = stats["m2"] / jnp.maximum(stats["count"] - 1.0, 1.0)
        return jnp.clip((o - stats["mean"])
                        / jnp.sqrt(var + self.eps),
                        -self.clip, self.clip)

    def obs(self, state):
        return self._normalize(state["wrap"],
                               self.inner.obs(state["inner"]))

    def step(self, state, action):
        s, o, r, d = self.inner.step(state["inner"], action)
        st = state["wrap"]
        count = st["count"] + 1.0
        delta = o - st["mean"]
        mean = st["mean"] + delta / count
        m2 = st["m2"] + delta * (o - mean)
        stats = {"count": count, "mean": mean, "m2": m2}
        return {"inner": s, "wrap": stats}, self._normalize(stats, o), r, d


class RewardScale(Wrapper):
    """Multiply rewards by a constant (stateless)."""

    def __init__(self, inner: Env, scale: float):
        super().__init__(inner)
        self.scale = scale

    def step(self, state, action):
        s, o, r, d = self.inner.step(state["inner"], action)
        return {"inner": s, "wrap": state["wrap"]}, o, r * self.scale, d


class ActionRepeat(Wrapper):
    """Repeat each action `repeat` times, summing rewards; once the
    episode ends mid-repeat the remaining sub-steps are masked out so
    the terminal observation/state freeze (frame-skip, stateless)."""

    def __init__(self, inner: Env, repeat: int):
        super().__init__(inner)
        assert repeat >= 1
        self.repeat = repeat

    def step(self, state, action):
        s, o, r, d = self.inner.step(state["inner"], action)

        def sub(carry, _):
            s, o, r, d = carry
            ns, no, nr, nd = self.inner.step(s, action)
            keep = d  # episode already over: freeze state/obs, no reward
            s = jax.tree_util.tree_map(
                lambda a, b: jnp.where(keep, a, b), s, ns)
            o = jnp.where(keep, o, no)
            r = r + jnp.where(keep, 0.0, nr)
            return (s, o, r, d | nd), None

        (s, o, r, d), _ = jax.lax.scan(sub, (s, o, r, d), None,
                                       length=self.repeat - 1)
        return {"inner": s, "wrap": state["wrap"]}, o, r, d
