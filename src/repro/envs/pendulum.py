"""Pendulum-v1 dynamics in pure jnp (continuous torque).

Mass/length/gravity live in the scenario pytree; `pendulum-rand` draws
a fresh variant per episode (domain randomization). Torque and speed
limits stay static — they define the action-space bounds and obs
normalization published in the spec.
"""
import jax
import jax.numpy as jnp

from repro.envs.api import Env
from repro.envs.registry import register
from repro.envs.spec import EnvSpec, box

# per-episode randomization bounds for the `pendulum-rand` family
RAND_RANGES = {"m": (0.7, 1.3), "l": (0.7, 1.3), "g": (8.0, 12.0)}


def _angle_normalize(x):
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi


class Pendulum(Env):
    max_speed = 8.0
    max_torque = 2.0
    dt = 0.05
    g = 10.0
    m = 1.0
    l = 1.0
    max_steps = 200

    @property
    def spec(self):
        return EnvSpec("pendulum",
                       observation=box((3,), low=-1.0, high=1.0),
                       action=box((1,), low=-self.max_torque,
                                  high=self.max_torque),
                       episode_len=self.max_steps)

    def default_scenario(self):
        return {"g": self.g, "m": self.m, "l": self.l}

    def reset_scenario(self, key, scn):
        k1, k2 = jax.random.split(key)
        th = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
        thdot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        return {"th": th, "thdot": thdot, "t": jnp.zeros((), jnp.int32)}

    def obs(self, state):
        return jnp.stack([jnp.cos(state["th"]), jnp.sin(state["th"]),
                          state["thdot"] / self.max_speed])

    def step(self, state, action):
        scn = state["scn"]
        u = jnp.clip(action.reshape(()), -self.max_torque,
                     self.max_torque)
        th, thdot = state["th"], state["thdot"]
        cost = (_angle_normalize(th) ** 2 + 0.1 * thdot ** 2
                + 0.001 * u ** 2)
        thdot = thdot + (3 * scn["g"] / (2 * scn["l"]) * jnp.sin(th)
                         + 3.0 / (scn["m"] * scn["l"] ** 2) * u) * self.dt
        thdot = jnp.clip(thdot, -self.max_speed, self.max_speed)
        th = th + thdot * self.dt
        t = state["t"] + 1
        s = {"th": th, "thdot": thdot, "t": t, "scn": scn}
        return s, self.obs(s), -cost, t >= self.max_steps


register("pendulum", Pendulum)
register("pendulum-rand",
         lambda ranges=None, **kw: Pendulum(
             ranges=dict(RAND_RANGES, **(ranges or {})), **kw))
