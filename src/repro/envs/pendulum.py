"""Pendulum-v1 dynamics in pure jnp (continuous torque)."""
import jax
import jax.numpy as jnp

from repro.envs.api import Env


def _angle_normalize(x):
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi


class Pendulum(Env):
    obs_dim = 3
    n_actions = 0
    act_dim = 1

    max_speed = 8.0
    max_torque = 2.0
    dt = 0.05
    g = 10.0
    m = 1.0
    l = 1.0
    max_steps = 200

    def reset(self, key):
        k1, k2 = jax.random.split(key)
        th = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
        thdot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        return {"th": th, "thdot": thdot, "t": jnp.zeros((), jnp.int32)}

    def obs(self, state):
        return jnp.stack([jnp.cos(state["th"]), jnp.sin(state["th"]),
                          state["thdot"] / self.max_speed])

    def step(self, state, action):
        u = jnp.clip(action.reshape(()), -self.max_torque, self.max_torque)
        th, thdot = state["th"], state["thdot"]
        cost = (_angle_normalize(th) ** 2 + 0.1 * thdot ** 2
                + 0.001 * u ** 2)
        thdot = thdot + (3 * self.g / (2 * self.l) * jnp.sin(th)
                         + 3.0 / (self.m * self.l ** 2) * u) * self.dt
        thdot = jnp.clip(thdot, -self.max_speed, self.max_speed)
        th = th + thdot * self.dt
        t = state["t"] + 1
        s = {"th": th, "thdot": thdot, "t": t}
        return s, self.obs(s), -cost, t >= self.max_steps
