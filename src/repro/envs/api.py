"""Zero-copy batch environment API (survey §4.2, TPU-native).

Environments are pure functions over jnp state — `reset`/`step` fuse into
the same XLA program as policy inference and the optimizer, so there is
no host↔device traffic at all (the TPU adaptation of Isaac Gym's
"Tensor API" zero-copy design). Batch simulation = `jax.vmap`.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


class Env:
    """Single-instance pure-functional environment; vmap for batches."""
    obs_dim: int
    n_actions: int = 0        # 0 -> continuous
    act_dim: int = 1

    def reset(self, key) -> dict:
        raise NotImplementedError

    def step(self, state: dict, action) -> Tuple[dict, jnp.ndarray,
                                                 jnp.ndarray, jnp.ndarray]:
        """-> (state, obs, reward, done)"""
        raise NotImplementedError

    def obs(self, state: dict) -> jnp.ndarray:
        raise NotImplementedError

    # -- batched convenience -----------------------------------------
    def reset_batch(self, key, n):
        return jax.vmap(self.reset)(jax.random.split(key, n))

    def step_batch(self, state, action):
        return jax.vmap(self.step)(state, action)

    def step_autoreset(self, state, action, key):
        """Vectorized step with per-env auto-reset on done (the standard
        batch-simulation pattern — episodes never block the batch)."""
        new_state, obs, reward, done = self.step_batch(state, action)
        n = done.shape[0]
        fresh = jax.vmap(self.reset)(jax.random.split(key, n))
        sel = lambda a, b: jnp.where(
            done.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
        state = jax.tree_util.tree_map(sel, fresh, new_state)
        obs = jax.vmap(self.obs)(state)
        return state, obs, reward, done
