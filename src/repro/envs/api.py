"""Zero-copy batch environment API v2 (survey §4.2, TPU-native).

Environments are pure functions over jnp state — `reset`/`step` fuse into
the same XLA program as policy inference and the optimizer, so there is
no host↔device traffic at all (the TPU adaptation of Isaac Gym's
"Tensor API" zero-copy design). Batch simulation = `jax.vmap`.

v2 adds three substrate pieces, mirroring the agent seam
(repro.core.agent):

  * every env publishes an `EnvSpec` (repro.envs.spec) — typed
    observation/action spaces with dtypes and bounds — instead of
    `obs_dim`/`n_actions`/`act_dim` class attributes (kept as derived
    properties for compatibility);
  * **scenario batching**: constructors accept a physics/layout
    parameter pytree (`scenario=` overrides, `ranges=` per-episode
    randomization bounds). The sampled scenario lives *inside the env
    state* under `state["scn"]`, so one `vmap`'d rollout batches a
    distribution of scenario variants (domain-randomized masses, grid
    sizes, goal placements) with zero changes to the rollout engine or
    Trainer;
  * `step_autoreset` surfaces the **pre-reset terminal observation**
    (the true successor obs) so bootstrapping at episode boundaries
    never sees the fresh-reset obs, and exposes an `autoreset_merge`
    hook that wrappers use to carry state (e.g. running obs statistics)
    across episode boundaries.

The name registry lives in repro.envs.registry (`envs.make("cartpole")`,
exactly parallel to `agent.make`).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.envs.spec import EnvSpec


class Env:
    """Single-instance pure-functional environment; vmap for batches.

    Subclasses implement `spec`, `reset_scenario(key, scn)`, `obs` and
    `step` (reading physics/layout from `state["scn"]`), and optionally
    `default_scenario` / `sample_scenario` for scenario batching.
    Envs that predate the scenario API may instead override `reset`
    directly — every base-class facility still works.
    """

    def __init__(self, scenario=None, ranges=None):
        base = {k: jnp.asarray(v)
                for k, v in self.default_scenario().items()}
        for k, v in (scenario or {}).items():
            if k not in base:
                raise KeyError(f"unknown scenario field {k!r}; "
                               f"available: {sorted(base)}")
            base[k] = jnp.asarray(v, base[k].dtype)
        for k in (ranges or {}):
            if k not in base:
                raise KeyError(f"unknown scenario range {k!r}; "
                               f"available: {sorted(base)}")
        self._scenario = base
        self._ranges = dict(ranges or {})

    # -- the contract --------------------------------------------------
    @property
    def spec(self) -> EnvSpec:
        raise NotImplementedError

    def reset_scenario(self, key, scn) -> dict:
        """Initial state (without the "scn" entry) for one scenario."""
        raise NotImplementedError

    def obs(self, state) -> jnp.ndarray:
        raise NotImplementedError

    def step(self, state, action) -> Tuple[dict, jnp.ndarray,
                                           jnp.ndarray, jnp.ndarray]:
        """-> (state, obs, reward, done)"""
        raise NotImplementedError

    # -- seed-API compatibility (derived from the spec) ----------------
    @property
    def obs_dim(self) -> int:
        return self.spec.obs_dim

    @property
    def n_actions(self) -> int:
        return self.spec.n_actions

    @property
    def act_dim(self) -> int:
        return self.spec.act_dim

    # -- scenario batching ---------------------------------------------
    def default_scenario(self) -> dict:
        """Physics/layout parameter pytree; {} = scenario-free env."""
        return {}

    def sample_scenario(self, key) -> dict:
        """Draw one scenario: base values with `ranges` entries sampled
        uniformly (integers inclusive, floats half-open) per episode —
        domain randomization happens here, once per reset."""
        scn = dict(self._scenario)
        for i, name in enumerate(sorted(self._ranges)):
            lo, hi = self._ranges[name]
            k = jax.random.fold_in(key, i)
            base = scn[name]
            if jnp.issubdtype(base.dtype, jnp.integer):
                scn[name] = jax.random.randint(
                    k, base.shape, int(lo), int(hi) + 1, base.dtype)
            else:
                scn[name] = jax.random.uniform(
                    k, base.shape, base.dtype, lo, hi)
        return scn

    def reset(self, key) -> dict:
        """Sample a scenario, then the initial state for it. The drawn
        scenario rides in `state["scn"]` so batched state <=> batched
        scenarios."""
        k_scn, k_state = jax.random.split(key)
        scn = self.sample_scenario(k_scn)
        state = dict(self.reset_scenario(k_state, scn))
        state["scn"] = scn
        return state

    # -- batched convenience -------------------------------------------
    def reset_batch(self, key, n):
        return jax.vmap(self.reset)(jax.random.split(key, n))

    def step_batch(self, state, action):
        return jax.vmap(self.step)(state, action)

    def autoreset_merge(self, fresh, new_state, sel):
        """Merge fresh (reset) and stepped state at episode boundaries;
        `sel(a, b)` picks a where the episode ended. Wrappers override
        to keep persistent wrapper state (e.g. obs statistics) alive
        across resets."""
        return jax.tree_util.tree_map(sel, fresh, new_state)

    def step_autoreset(self, state, action, key):
        """Vectorized step with per-env auto-reset on done (the standard
        batch-simulation pattern — episodes never block the batch).

        Returns `(state, obs, reward, done)` where `obs` is the
        **pre-reset** observation emitted by `step` — at `done` steps
        this is the terminal observation, NOT the fresh-reset one, so
        consumers can bootstrap correctly at episode boundaries. The
        post-reset observation of the new episode is `obs(state)`.
        """
        new_state, obs, reward, done = self.step_batch(state, action)
        n = done.shape[0]
        fresh = jax.vmap(self.reset)(jax.random.split(key, n))
        sel = lambda a, b: jnp.where(
            done.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
        state = self.autoreset_merge(fresh, new_state, sel)
        return state, obs, reward, done
