"""CartPole-v1 dynamics in pure jnp (discrete, 2 actions).

Physics constants live in the scenario pytree (`state["scn"]`), so the
same `vmap`'d rollout can train across a batch of pole-mass/length/
force variants — the `cartpole-rand` scenario family draws a fresh
variant per episode (domain randomization).
"""
import jax
import jax.numpy as jnp

from repro.envs.api import Env
from repro.envs.registry import register
from repro.envs.spec import EnvSpec, box, discrete

# per-episode randomization bounds for the `cartpole-rand` family
RAND_RANGES = {"masspole": (0.05, 0.2), "length": (0.3, 0.7),
               "force_mag": (8.0, 12.0)}


class CartPole(Env):
    gravity = 9.8
    masscart = 1.0
    masspole = 0.1
    length = 0.5
    force_mag = 10.0
    tau = 0.02
    x_lim = 2.4
    theta_lim = 12 * jnp.pi / 180
    max_steps = 200

    @property
    def spec(self):
        return EnvSpec("cartpole",
                       observation=box((4,)),
                       action=discrete(2),
                       episode_len=self.max_steps)

    def default_scenario(self):
        return {"gravity": self.gravity, "masscart": self.masscart,
                "masspole": self.masspole, "length": self.length,
                "force_mag": self.force_mag}

    def reset_scenario(self, key, scn):
        s = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        return {"s": s, "t": jnp.zeros((), jnp.int32)}

    def obs(self, state):
        return state["s"]

    def step(self, state, action):
        scn = state["scn"]
        x, x_dot, th, th_dot = state["s"]
        force = jnp.where(action > 0, scn["force_mag"],
                          -scn["force_mag"])
        total_mass = scn["masscart"] + scn["masspole"]
        pml = scn["masspole"] * scn["length"]
        costh, sinth = jnp.cos(th), jnp.sin(th)
        temp = (force + pml * th_dot ** 2 * sinth) / total_mass
        th_acc = (scn["gravity"] * sinth - costh * temp) / (
            scn["length"] * (4.0 / 3.0 - scn["masspole"] * costh ** 2
                             / total_mass))
        x_acc = temp - pml * th_acc * costh / total_mass
        x = x + self.tau * x_dot
        x_dot = x_dot + self.tau * x_acc
        th = th + self.tau * th_dot
        th_dot = th_dot + self.tau * th_acc
        s = jnp.stack([x, x_dot, th, th_dot])
        t = state["t"] + 1
        done = ((jnp.abs(x) > self.x_lim) | (jnp.abs(th) > self.theta_lim)
                | (t >= self.max_steps))
        return ({"s": s, "t": t, "scn": scn}, s, jnp.float32(1.0), done)


register("cartpole", CartPole)
register("cartpole-rand",
         lambda ranges=None, **kw: CartPole(
             ranges=dict(RAND_RANGES, **(ranges or {})), **kw))
