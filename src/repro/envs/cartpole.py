"""CartPole-v1 dynamics in pure jnp (discrete, 2 actions)."""
import jax
import jax.numpy as jnp

from repro.envs.api import Env


class CartPole(Env):
    obs_dim = 4
    n_actions = 2

    gravity = 9.8
    masscart = 1.0
    masspole = 0.1
    length = 0.5
    force_mag = 10.0
    tau = 0.02
    x_lim = 2.4
    theta_lim = 12 * jnp.pi / 180
    max_steps = 200

    def reset(self, key):
        s = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        return {"s": s, "t": jnp.zeros((), jnp.int32)}

    def obs(self, state):
        return state["s"]

    def step(self, state, action):
        x, x_dot, th, th_dot = state["s"]
        force = jnp.where(action > 0, self.force_mag, -self.force_mag)
        total_mass = self.masscart + self.masspole
        pml = self.masspole * self.length
        costh, sinth = jnp.cos(th), jnp.sin(th)
        temp = (force + pml * th_dot ** 2 * sinth) / total_mass
        th_acc = (self.gravity * sinth - costh * temp) / (
            self.length * (4.0 / 3.0 - self.masspole * costh ** 2
                           / total_mass))
        x_acc = temp - pml * th_acc * costh / total_mass
        x = x + self.tau * x_dot
        x_dot = x_dot + self.tau * x_acc
        th = th + self.tau * th_dot
        th_dot = th_dot + self.tau * th_acc
        s = jnp.stack([x, x_dot, th, th_dot])
        t = state["t"] + 1
        done = ((jnp.abs(x) > self.x_lim) | (jnp.abs(th) > self.theta_lim)
                | (t >= self.max_steps))
        return ({"s": s, "t": t}, s, jnp.float32(1.0), done)
