"""Host-pipeline wrapper (survey Fig. 5a baseline).

A `Wrapper` that forces every `step` through an `io_callback` to the
host — recreating the CPU-simulation pipeline where intermediate data is
copied host<->device every iteration. Used ONLY by
benchmarks/fig5_simulation.py to measure what zero-copy on-device
simulation buys (survey §4.2); being a regular wrapper it composes with
the rest of the stack and inherits the spec/registry plumbing for free
(deliberately minus a registry name — it is a measurement harness, not
an environment).

Why this wrapper stays QUEUE-FREE while the trainer grew a pipelined
mode (repro.core.pipeline): the trajectory queue decouples experience
*generation* from *learning*, letting the producer run `depth`
iterations ahead. It cannot decouple the env from *itself* — stepping
is closed-loop (step t+1's input is step t's output), and here that
loop detours through host memory every step. No queue depth can
prefetch across that dependency; the host round-trip serializes the
rollout from the inside. Under ``pipeline=True`` the wrapper therefore
just executes inside the producer program, unchanged in numerics and
un-hidden in cost (tests/test_pipeline.py pins both) — which is
precisely what makes it the Fig. 5a baseline the pipelined/on-device
paths are measured against.
"""
import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.envs.api import Env
from repro.envs.wrappers import Wrapper


class HostPipelined(Wrapper):
    def __init__(self, inner: Env):
        super().__init__(inner)

    def step(self, state, action):
        # round-trip the (state, action) through host memory
        def host_step(inner_state, action):
            inner_state = jax.tree_util.tree_map(np.asarray, inner_state)
            s, o, r, d = self.inner.step(inner_state, jnp.asarray(action))
            return (jax.tree_util.tree_map(np.asarray, s), np.asarray(o),
                    np.float32(r), np.bool_(d))

        shapes = jax.eval_shape(self.inner.step, state["inner"], action)
        s, o, r, d = io_callback(host_step, shapes, state["inner"],
                                 action)
        return {"inner": s, "wrap": state["wrap"]}, o, r, d
