"""Host-pipeline environment wrapper (survey Fig. 5a baseline).

Forces every `step` through an `io_callback` to the host — recreating the
CPU-simulation pipeline where intermediate data is copied host<->device
every iteration. Used ONLY by benchmarks/fig5_simulation.py to measure
what zero-copy on-device simulation buys (survey §4.2).
"""
import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.envs.api import Env


class HostPipelined(Env):
    def __init__(self, inner: Env):
        self.inner = inner
        self.obs_dim = inner.obs_dim
        self.n_actions = inner.n_actions
        self.act_dim = inner.act_dim

    def reset(self, key):
        return self.inner.reset(key)

    def obs(self, state):
        return self.inner.obs(state)

    def step(self, state, action):
        # round-trip the (state, action) through host memory
        def host_step(state, action):
            state = jax.tree_util.tree_map(np.asarray, state)
            s, o, r, d = self.inner.step(state, jnp.asarray(action))
            return (jax.tree_util.tree_map(np.asarray, s), np.asarray(o),
                    np.float32(r), np.bool_(d))

        shapes = jax.eval_shape(self.inner.step, state, action)
        return io_callback(host_step, shapes, state, action)
