"""Environment name registry — `envs.make("cartpole", **kw)`, exactly
parallel to `agent.make` (repro.core.agent): environments and their
wrapped/scenario variants self-register by name when `repro.envs` is
imported, so the CLI, examples, benchmarks and the conformance suite
pick new entries up automatically with no hand-maintained tables.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.envs.api import Env

_REGISTRY: Dict[str, Callable[..., Env]] = {}


def register(name: str, factory: Callable[..., Env]) -> None:
    """Register an Env factory under `name` (called with **kwargs)."""
    _REGISTRY[name] = factory


def available():
    """Names of all registered environments."""
    import repro.envs  # noqa: F401 — triggers self-registration
    return tuple(sorted(_REGISTRY))


def make(name: str, **kwargs) -> Env:
    """Construct a registered environment by name from config."""
    import repro.envs  # noqa: F401 — triggers self-registration
    if name not in _REGISTRY:
        raise KeyError(f"unknown environment {name!r}; available: "
                       f"{', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[name](**kwargs)
