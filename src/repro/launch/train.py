"""End-to-end LM training driver (learner side of the survey's
actor/learner split).

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 200 --batch 16 --seq 128
Production dry-run path is launch/dryrun.py; this driver runs REAL steps
on whatever devices exist (uses the mesh when >1 device).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.data import TokenStream
from repro.models import build_model
from repro.models.model import ModelOpts
from repro.optim import adamw, clip_by_global_norm, cosine_schedule


def make_train_step(model, optimizer):
    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state = optimizer.apply(params, opt_state, grads)
        return params, opt_state, loss, metrics
    return step


def train(arch="smollm-360m", reduced=True, steps=200, batch=16, seq=128,
          lr=3e-4, seed=0, ckpt=None, log_every=10, dtype="float32",
          remat=False):
    model = build_model(arch, ModelOpts(dtype=dtype, remat=remat),
                        reduced=reduced)
    cfg = model.cfg
    stream = TokenStream(cfg.vocab, seq, batch, seed=seed)
    optimizer = clip_by_global_norm(
        adamw(cosine_schedule(lr, steps, warmup=steps // 20)), 1.0)
    params = model.init(jax.random.PRNGKey(seed))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    opt_state = optimizer.init(params)
    step_fn = make_train_step(model, optimizer)
    history = []
    t0 = time.time()
    fe = None
    if cfg.frontend == "vision_stub":
        fe = 0.02 * jnp.ones((batch, cfg.frontend_tokens,
                              cfg.frontend_dim or cfg.d_model))
    elif cfg.frontend == "audio_stub":
        fe = 0.02 * jnp.ones((batch, cfg.enc_tokens, cfg.d_model))
    for i in range(steps):
        b = stream.batch_at(i)
        if fe is not None:
            b = dict(b, frontend=fe)
        params, opt_state, loss, metrics = step_fn(params, opt_state, b)
        if i % log_every == 0 or i == steps - 1:
            ce = float(metrics["ce"])
            history.append({"step": i, "ce": round(ce, 4),
                            "elapsed_s": round(time.time() - t0, 1)})
            print(json.dumps(history[-1]))
    if ckpt:
        save_checkpoint(ckpt, {"params": params}, step=steps)
    return {"arch": arch, "n_params": int(n_params),
            "optimal_ce": round(stream.optimal_ce(), 4),
            "history": history}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    out = train(args.arch, args.reduced, args.steps, args.batch, args.seq,
                args.lr, ckpt=args.ckpt)
    print(json.dumps({k: v for k, v in out.items() if k != "history"}))


if __name__ == "__main__":
    main()
