"""Sharding rules: param/cache/batch PartitionSpecs for the production
mesh (megatron-style tensor parallel on `model`, data parallel on
`pod`+`data`, optional ZeRO-3/FSDP over `data`).

Rules are name-based with divisibility-checked fallbacks: if the
preferred dim of a leaf doesn't divide by the axis size (e.g. smollm's
15 heads on a 16-way model axis) the rule falls through to the next
candidate dim and ultimately to replication — every decision is
auditable via `explain_sharding`.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


# (path regex, candidate dims to shard over `model`, in priority order)
# dims are indices *from the right* of the leaf (robust to stacked
# leading layer dims from scan-over-layers).
_MODEL_RULES = (
    (r"embed/tok$", (2,)),             # (V, d): vocab
    (r"embed/unembed$", (1,)),         # (d, V): vocab
    (r"projector$", (1,)),             # (fd, d)
    (r"(mixer|xattn)/wq$", (2, 1)),    # (d, H, hd): heads, then hd
    (r"(mixer|xattn)/wk$", (2, 1)),
    (r"(mixer|xattn)/wv$", (2, 1)),
    (r"(mixer|xattn)/wo$", (3, 2)),    # (H, hd, d): heads
    (r"mixer/wuq$", (2, 1)),           # MLA (rq, H, hd)
    (r"mixer/wqr$", (2,)),
    (r"mixer/wuk$", (2,)),             # (rkv, H, hd)
    (r"mixer/wuv$", (2,)),
    (r"mixer/wdq$", (1,)),             # (d, rq)
    (r"ffn/(wi|wg)$", (1,)),           # dense mlp (d, f) OR moe (E, d, f)
    (r"ffn/wo$", (2,)),                # dense (f, d) OR moe (E, f, d)
    (r"shared/(wi|wg)$", (1,)),        # (d, f*ns)
    (r"shared/wo$", (2,)),             # (f*ns, d)
    (r"mixer/(wr|wk|wv|wg)$", (1,)),   # rwkv (d, d): columns
    (r"mixer/wo$", (2,)),              # rwkv (d, d): rows
    (r"mixer/in_proj$", (1,)),         # mamba (d, 2di)
    (r"mixer/out_proj$", (2,)),        # (di, d)
    (r"mixer/(conv_w|conv_b|dt_bias|D)$", (1,)),  # (..., di)
    (r"mixer/bc_proj$", (2,)),         # (di, 2N)
    (r"mixer/dt_proj$", (2,)),         # (di, 1)
    (r"mixer/A_log$", (2,)),           # (di, N)
)

_MOE_EXPERT_RULE = re.compile(r"ffn/(wi|wg|wo)$")
_ATTN_RULE = re.compile(r"(mixer|xattn)/(wq|wk|wv|wo|wuq|wqr|wuk|wuv|"
                        r"wdq)$")
_EMBED_RULE = re.compile(r"embed/(tok|unembed)$")

# Sharding policies (the §Perf hillclimb levers — "baseline" is the
# paper-faithful naive always-shard-something scheme recorded in the
# baseline roofline table):
#   baseline        — rule table with full fallback chain
#   attn_heads_only — attention leaves shard ONLY when the head dim
#                     divides; otherwise replicate (avoids score-matrix
#                     all-reduces when heads < model axis)
#   +embed_d        — embedding/unembedding shard d_model instead of
#                     vocab (decode: one logits psum instead of a full
#                     table all-gather)
#   pure_dp         — no tensor parallelism at all: params replicated,
#                     batch sharded over EVERY mesh axis (the "small
#                     models don't need TP" lever; collective cost
#                     collapses to one grad all-reduce)
POLICIES = ("baseline", "attn_heads_only", "attn_heads_only+embed_d",
            "pure_dp")


def _path_str(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_pspec(path_str: str, shape, mesh, fsdp: bool = False,
                policy: str = "baseline"):
    """PartitionSpec for one param leaf."""
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1)
    spec = [None] * len(shape)
    if policy == "pure_dp":
        if fsdp and data > 1:  # ZeRO storage sharding only
            cands = sorted((s, i) for i, s in enumerate(shape)
                           if s % data == 0 and s >= data)
            if cands:
                spec[cands[-1][1]] = "data"
        return P(*spec)
    # expert-parallel: MoE expert dim (rank-3 ffn leaves) over `model`
    is_moe = (_MOE_EXPERT_RULE.search(path_str) and len(shape) >= 3
              and shape[-3] >= 4)
    if is_moe and shape[-3] % model == 0:
        spec[len(shape) - 3] = "model"
    else:
        for rx, dims in _MODEL_RULES:
            if re.search(rx, path_str):
                if policy != "baseline" and _ATTN_RULE.search(path_str):
                    dims = dims[:1]   # heads or nothing — no fallback
                if "embed_d" in policy and _EMBED_RULE.search(path_str):
                    # shard d_model instead of vocab
                    dims = ((1,) if path_str.endswith("tok") else (2,))
                for dfr in dims:  # dim index from the right
                    i = len(shape) - dfr
                    if 0 <= i < len(shape) and shape[i] % model == 0 \
                            and shape[i] >= model:
                        spec[i] = "model"
                        break
                break
    if fsdp and data > 1:
        # ZeRO-3: shard the largest remaining free dim over `data`
        cands = sorted((s, i) for i, s in enumerate(shape)
                       if spec[i] is None and s % data == 0 and s >= data)
        if cands:
            spec[cands[-1][1]] = "data"
    return P(*spec)


def shard_params(params_struct, mesh, fsdp: bool = False,
                 policy: str = "baseline"):
    """Pytree of NamedSharding matching a params (or opt-moment) tree."""
    def one(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0 or leaf.size < 1024:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, param_pspec(ps, leaf.shape, mesh, fsdp,
                                               policy))
    return jax.tree_util.tree_map_with_path(one, params_struct)


def explain_sharding(params_struct, mesh, fsdp: bool = False, limit=None,
                     policy: str = "baseline"):
    rows = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            params_struct)[0][:limit]:
        ps = _path_str(path)
        spec = (P() if leaf.ndim == 0 or leaf.size < 1024
                else param_pspec(ps, leaf.shape, mesh, fsdp, policy))
        rows.append((ps, leaf.shape, spec))
    return rows


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def _bdims(mesh, policy="baseline"):
    names = (("pod", "data", "model") if policy == "pure_dp"
             else ("pod", "data"))
    axes = tuple(a for a in names if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def batch_sharding(mesh, batch_struct, policy="baseline"):
    """Shard every batch leaf on dim 0 (global batch)."""
    bx = _bdims(mesh, policy)
    n = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    if policy == "pure_dp":
        n *= mesh.shape.get("model", 1)

    def one(leaf):
        if leaf.shape and leaf.shape[0] % n == 0 and leaf.shape[0] >= n:
            return NamedSharding(mesh, P(bx))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(one, batch_struct)


def cache_sharding(mesh, cache_struct, batch: int):
    """KV/recurrent-state cache shardings.

    batch >= data axis: shard batch dim. batch == 1 (long_500k): shard
    the *sequence/capacity* dim of kv-type leaves over `data` (distributed
    flash-decode — XLA inserts the partial-softmax collectives), and the
    head/channel dim of recurrent state over `model`.
    """
    n = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)
    bx = _bdims(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        # leading stacked-layer dim from scan stacks shifts indices by 1
        off = 1 if ".stack" in ps or ps.startswith("stack") else 0
        shape = leaf.shape
        spec = [None] * len(shape)
        bdim = off
        if shape[bdim] % n == 0 and shape[bdim] >= n:
            spec[bdim] = bx
        else:
            # batch too small: shard capacity (kv) over data
            name = ps.rsplit("/", 1)[-1]
            if name in ("k", "v", "ckv", "kr", "ek", "ev") \
                    and len(shape) > bdim + 1 \
                    and shape[bdim + 1] % data == 0 \
                    and shape[bdim + 1] >= data:
                spec[bdim + 1] = "data"
            elif name in ("S",) and shape[bdim + 1] % model == 0:
                spec[bdim + 1] = "model"   # rwkv state heads
            elif name in ("conv", "ssm") and shape[-2 if name == "ssm"
                                                   else -1] % model == 0:
                spec[len(shape) - (2 if name == "ssm" else 1)] = "model"
        # also shard kv heads/channels over model when possible
        name = ps.rsplit("/", 1)[-1]
        if name in ("k", "v", "ek", "ev") and len(shape) >= bdim + 3:
            kvh_dim = bdim + 2
            if spec[kvh_dim] is None and shape[kvh_dim] % model == 0 \
                    and shape[kvh_dim] >= model:
                spec[kvh_dim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_struct)
