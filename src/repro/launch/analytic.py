"""Analytic FLOP/byte cost model for the roofline terms.

WHY ANALYTIC: XLA's `cost_analysis()` counts a while-loop body ONCE
(verified experimentally — scan10 of a matmul reports 1 matmul of
flops), and this framework deliberately lowers layers, flash-attention
kv blocks and SSM chunks as scans to keep compile time bounded. HLO
flops/bytes therefore undercount by the trip counts. The compute and
memory roofline terms below are exact closed forms per architecture;
the HLO numbers are still recorded as a cross-check, and the collective
term stays HLO-derived (with a scan-correction probe, see dryrun.py).

All counts are GLOBAL (whole step, all chips); dryrun divides by chips.
"""
from __future__ import annotations

from repro.configs.base import (ATTN, ATTN_LOCAL, MLA, RWKV, MAMBA,
                                ModelConfig, ShapeConfig)

WKV_CHUNK = 64
MAMBA_CHUNK = 32


def _attn_flops_token(cfg, ctx):
    """Per-token flops of one GQA layer at average context `ctx`."""
    H, KVH, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    proj = 2 * d * H * hd * 2 + 2 * d * KVH * hd * 2   # q,o + k,v
    attn = 2 * ctx * H * hd * 2                        # qk^T + pv
    return proj + attn


def _mla_flops_token(cfg, ctx, decode=False):
    H, hd, hr = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    d, rq, rkv = cfg.d_model, cfg.q_lora_rank or cfg.d_model, \
        cfg.kv_lora_rank
    proj = 2 * d * rq + 2 * rq * H * (hd + hr) \
        + 2 * d * (rkv + hr) + 2 * H * hd * d          # down/up q, dkv, o
    if decode:  # absorbed form: score vs latent cache
        absorb = 2 * H * hd * rkv * 2                  # q absorb + v expand
        attn = 2 * ctx * H * (rkv + hr) * 2
        return proj + absorb + attn
    expand = 2 * rkv * H * hd * 2                      # k_nope, v expand
    attn = 2 * ctx * H * (hd + hr) + 2 * ctx * H * hd
    return proj + expand + attn


def _rwkv_flops_token(cfg):
    d, H, N = cfg.d_model, cfg.n_heads, cfg.head_dim
    L = WKV_CHUNK
    proj = 2 * d * d * 5 + 2 * d * (5 * 32) * 2 + 2 * d * 64 * 2
    wkv = H * (8 * L * N + 6 * N * N)  # intra decay/score/pv + inter/state
    cm = 2 * d * cfg.d_ff * 2 + 2 * d * d              # channel mix
    return proj + wkv + cm


def _mamba_flops_token(cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    proj = 2 * d * 2 * di + 2 * di * d
    conv = 2 * cfg.ssm_conv * di
    bc = 2 * di * 2 * N + 2 * di
    scan = 10 * di * N                                 # assoc-scan + y
    return proj + conv + bc + scan


def _ffn_flops_token(cfg, layer_idx):
    d = cfg.d_model
    if cfg.is_moe_layer(layer_idx):
        m = cfg.moe
        return (6 * d * m.d_ff * (m.top_k + m.n_shared)
                + 2 * d * m.n_experts)
    return 6 * d * cfg.d_ff


def fwd_flops_per_token(cfg: ModelConfig, ctx: float,
                        decode: bool = False) -> float:
    """Forward flops per (decoder) token at average attention context
    `ctx` (train/prefill causal: (S-1)/2; decode: full S)."""
    total = 2 * cfg.d_model * cfg.vocab                # unembed
    for i, kind in enumerate(cfg.pattern()):
        if kind == ATTN:
            total += _attn_flops_token(cfg, ctx)
        elif kind == ATTN_LOCAL:
            total += _attn_flops_token(cfg, min(ctx, cfg.window))
        elif kind == MLA:
            total += _mla_flops_token(cfg, ctx, decode)
        elif kind == RWKV:
            total += _rwkv_flops_token(cfg)
            continue                                   # ffn built-in
        elif kind == MAMBA:
            total += _mamba_flops_token(cfg)
        total += _ffn_flops_token(cfg, i)
    return total


def encoder_flops(cfg: ModelConfig, batch: int) -> float:
    """Whisper encoder / frontend-stub consumer flops (per step)."""
    if not cfg.enc_layers:
        if cfg.frontend == "vision_stub":
            fd = cfg.frontend_dim or cfg.d_model
            return 2 * fd * cfg.d_model * cfg.frontend_tokens * batch
        return 0.0
    Te, d = cfg.enc_tokens, cfg.d_model
    per_tok = (8 * d * d + 2 * Te * cfg.n_heads * cfg.head_dim * 2
               + 4 * d * cfg.d_ff)
    return per_tok * Te * batch


def step_flops(cfg: ModelConfig, shape: ShapeConfig,
               remat: bool = True) -> float:
    """Global flops of one step of the given mode."""
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        ctx = (S - 1) / 2
        fwd = fwd_flops_per_token(cfg, ctx) * B * S + encoder_flops(cfg, B)
        factor = 4.0 if remat else 3.0   # fwd + 2x bwd (+1 remat refwd)
        return fwd * factor
    if shape.mode == "prefill":
        ctx = (S - 1) / 2
        return fwd_flops_per_token(cfg, ctx) * B * S + encoder_flops(cfg, B)
    # decode: one token against full context
    ntok = B * 1
    fe = encoder_flops(cfg, 0)  # frontend consumed at prefill, not decode
    return fwd_flops_per_token(cfg, S, decode=True) * ntok + fe


# ---------------------------------------------------------------------------
# HBM traffic model (per chip)
# ---------------------------------------------------------------------------

def cache_bytes(cfg: ModelConfig, shape: ShapeConfig, act_bytes=2) -> float:
    """Global KV/state cache bytes at capacity seq_len."""
    B, S = shape.global_batch, shape.seq_len
    total = 0
    for kind in cfg.pattern():
        if kind == ATTN:
            total += B * S * cfg.n_kv_heads * cfg.head_dim * 2 * act_bytes
        elif kind == ATTN_LOCAL:
            C = min(S, cfg.window)
            total += B * C * cfg.n_kv_heads * cfg.head_dim * 2 * act_bytes
        elif kind == MLA:
            total += B * S * (cfg.kv_lora_rank + cfg.rope_head_dim) \
                * act_bytes
        elif kind == RWKV:
            total += B * cfg.n_heads * cfg.head_dim ** 2 * 4 \
                + 2 * B * cfg.d_model * act_bytes
        elif kind == MAMBA:
            di = cfg.ssm_expand * cfg.d_model
            total += B * di * cfg.ssm_state * 4 \
                + B * (cfg.ssm_conv - 1) * di * act_bytes
    if cfg.enc_layers:
        total += cfg.n_layers * B * cfg.enc_tokens * cfg.n_kv_heads \
            * cfg.head_dim * 2 * act_bytes
    return total


def step_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                   param_bytes=4, moment_bytes=4, act_bytes=2,
                   fsdp=False, model_axis=16, data_axis=16) -> float:
    """Per-chip HBM traffic of one step (weights + activations + cache).

    Weight traffic counts the *local shard* (tensor-parallel over
    `model`; FSDP additionally shards storage over `data`, but the
    all-gathered copy is still read from HBM once per use, so the read
    traffic stays P/model_axis)."""
    P = cfg.param_count()
    B, S = shape.global_batch, shape.seq_len
    p_read_local = P * param_bytes / model_axis
    if shape.mode == "train":
        # fwd read + bwd read + remat read + grad write + opt read/write
        p_store_local = P / (model_axis * (data_axis if fsdp else 1))
        weights = (p_read_local * 3
                   + p_store_local * (param_bytes * 2    # grad w + p w
                                      + moment_bytes * 4))  # m,v r+w
        tokens_local = B * S / chips * model_axis  # activations are
        # sharded over batch only; model axis replicates token activations
        acts = tokens_local * cfg.d_model * act_bytes * cfg.n_layers * 12
        return weights + acts * (1 / model_axis)  # heads/ffn sharded
    if shape.mode == "prefill":
        tokens_local = B * S / chips * model_axis
        acts = tokens_local * cfg.d_model * act_bytes * cfg.n_layers * 8
        cache = cache_bytes(cfg, shape, act_bytes) / chips
        return p_read_local + acts / model_axis + cache
    # decode: weights + full cache read per token
    cache = cache_bytes(cfg, shape, act_bytes) / chips
    return p_read_local + cache
