"""Policy-serving launcher: offered-load benchmark over the
repro.core.serving engine (survey §3.3 centralized inference — the
traffic-facing mirror of repro.launch.rl_train).

  PYTHONPATH=src python -m repro.launch.serve_policy --algo ppo \
      --env cartpole --load 500,2000 --buckets "1,4,16;16" --quick

Trains a policy briefly (or restores one with --ckpt), publishes it
into a versioned ParamStore, then replays an open-loop arrival process
at each offered load (requests/second) against each bucket
configuration: requests are admitted FIFO, padded to the smallest
fitting bucket (one compile per bucket, pinned flat), and hot-swapped
onto fresh params halfway through every cell (zero recompiles, by
construction — params are traced inputs). Per-request latency is
charged from the *scheduled* arrival, so queueing delay under
overload shows up in the percentiles, exactly like a production load
generator.

Always writes BENCH_serve.json (repo root unless --out redirects it,
repro-bench/v1): one row per
(load x bucket-config) cell with p50/p99 latency and delivered
throughput, plus the serve/compile_flat row pinning
recompiles_after_warmup=0 across all cells and hot-swaps
(tests/test_bench_schema.py validates both, --quick output included).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

# repo-root shim so `python -m repro.launch.serve_policy` can reach the
# benchmarks package (the BENCH_*.json writer) from any cwd
_REPO_ROOT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "..", ".."))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

ALGOS = ("a3c", "dqn", "impala", "ppo")


def parse_buckets(spec: str):
    """Bucket grammar: semicolon-separated configurations, each a
    comma-separated strictly increasing list of positive micro-batch
    sizes — e.g. "1,4,16;8,32" is two configurations. Validated here
    (jax-free, so bad flags fail before anything trains); the engine
    re-validates through serving.validate_buckets."""
    configs = []
    for part in spec.split(";"):
        if not part.strip():
            raise ValueError(f"empty bucket configuration in {spec!r}")
        try:
            cfg_b = tuple(int(b) for b in part.split(","))
        except ValueError:
            raise ValueError(f"bad bucket configuration {part!r}: "
                             f"expected comma-separated integers") \
                from None
        if any(b <= 0 for b in cfg_b) or \
                any(b <= a for a, b in zip(cfg_b, cfg_b[1:])):
            raise ValueError(
                f"bad bucket configuration {part!r}: sizes must be "
                f"positive and strictly increasing")
        configs.append(cfg_b)
    return configs


def parse_loads(spec: str):
    try:
        loads = tuple(float(x) for x in spec.split(","))
    except ValueError:
        raise ValueError(f"bad --load {spec!r}: expected "
                         f"comma-separated requests/second") from None
    if not loads or any(x <= 0 for x in loads):
        raise ValueError(f"offered loads must be positive, got {spec!r}")
    return loads


def run_offered_load(engine, obs_rows, load_rps, n, swap_params=None):
    """Open-loop load replay: request i arrives at start + i/load_rps
    (virtual schedule mapped onto the real clock); the engine serves as
    fast as it can, sleeping only when the queue is empty and the next
    arrival is in the future. Latency = completion - scheduled arrival,
    so a too-slow engine accumulates queueing delay instead of secretly
    throttling the load. Halfway through, `swap_params` (if given) is
    hot-swapped in — live traffic, zero recompiles."""
    start = time.perf_counter() + 0.002
    arrivals = [start + i / load_rps for i in range(n)]
    submitted, swapped = 0, False
    lats, versions = [], set()
    last_done = start
    while len(lats) < n:
        now = time.perf_counter()
        while submitted < n and arrivals[submitted] <= now:
            engine.submit(obs_rows[submitted % len(obs_rows)],
                          arrival=arrivals[submitted])
            submitted += 1
        if not len(engine.batcher):
            time.sleep(max(0.0,
                           arrivals[submitted] - time.perf_counter()))
            continue
        if swap_params is not None and not swapped and len(lats) >= n // 2:
            engine.store.publish(swap_params)
            swapped = True
        for r in engine.step():
            lats.append(r["latency_s"])
            versions.add(r["version"])
        last_done = time.perf_counter()
    lat_ms = np.asarray(lats) * 1e3
    return {"p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "throughput_rps": n / (last_done - start),
            "offered_rps": load_rps, "n": n,
            "hot_swaps": int(swapped), "versions": len(versions)}


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve_policy",
        description="Batched low-latency policy serving: offered-load "
                    "p50/p99 benchmark over repro.core.serving.")
    ap.add_argument("--algo", default="ppo", choices=ALGOS)
    ap.add_argument("--env", default="cartpole", metavar="ENV",
                    help="registered environment (repro.envs registry)")
    ap.add_argument("--load", default="300,1200", metavar="RPS,RPS,...",
                    help="offered loads in requests/second; one bench "
                         "row per load x bucket-config cell")
    ap.add_argument("--buckets", default="1,4,16;8,32",
                    metavar="B,B;B,...",
                    help="bucket configurations: semicolon-separated, "
                         "each an ascending comma list of micro-batch "
                         "sizes a request batch is padded to (one "
                         "compile per bucket, flat under traffic)")
    ap.add_argument("--requests", type=int, default=600,
                    help="requests replayed per cell")
    ap.add_argument("--train-iters", type=int, default=20,
                    help="Trainer iterations before serving (0 = serve "
                         "the freshly initialized policy)")
    ap.add_argument("--ckpt", default=None, metavar="PATH",
                    help="serve params restored from a repro.checkpoint "
                         "archive instead of training here")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="directory for BENCH_serve.json (default: "
                         "repo root — the committed trajectory; tests "
                         "pass a temp dir so suite runs never dirty "
                         "the committed full-run file)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests/iterations, "
                         "default loads 500,2000 and buckets 4,16;16")
    return ap


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.quick:
        if args.load == ap.get_default("load"):
            args.load = "500,2000"
        if args.buckets == ap.get_default("buckets"):
            args.buckets = "4,16;16"
        if args.requests == ap.get_default("requests"):
            args.requests = 160
        if args.train_iters == ap.get_default("train_iters"):
            args.train_iters = 4
    try:
        loads = parse_loads(args.load)
        configs = parse_buckets(args.buckets)
    except ValueError as e:
        ap.error(str(e))

    import jax
    import repro.envs as envs
    from benchmarks.common import write_bench_json
    from repro.core.serving import ParamStore, ServeEngine
    from repro.core.trainer import Trainer, TrainerConfig

    if args.env not in envs.available():
        ap.error(f"--env {args.env} not registered; available: "
                 f"{envs.available()}")
    env = envs.make(args.env)
    cfg = TrainerConfig(algo=args.algo, iters=max(args.train_iters, 1),
                        superstep=min(4, max(args.train_iters, 1)),
                        n_envs=8, unroll=16, seed=args.seed,
                        log_every=max(args.train_iters, 1))
    trainer = Trainer(env, cfg)
    t0 = time.time()
    store = ParamStore()
    if args.ckpt is not None:
        store.load_checkpoint(args.ckpt, trainer.agent)
        train_s = 0.0
        source = "checkpoint"
    else:
        state, _ = trainer.fit() if args.train_iters > 0 else \
            (trainer.agent.init(jax.random.PRNGKey(args.seed)), None)
        store.publish_from_state(trainer.agent, state)
        train_s = time.time() - t0
        source = "trained-in-process" if args.train_iters > 0 \
            else "fresh-init"
    # the hot-swap payload: same shapes (template-validated), fresh
    # values — published mid-cell to prove live traffic never recompiles
    _, base_params = store.get()
    swap_params = jax.tree_util.tree_map(
        lambda a: a * (1 + 1e-3) if jax.numpy.issubdtype(
            a.dtype, jax.numpy.floating) else a, base_params)

    spec = env.spec
    obs_rows = np.asarray(jax.vmap(spec.observation.sample)(
        jax.random.split(jax.random.PRNGKey(args.seed + 1),
                         min(args.requests, 256))))

    rows, cells = [], []
    warmup_compiles = total_compiles = hot_swaps = 0
    for cfg_b in configs:
        engine = ServeEngine(trainer.agent.policy, spec.observation,
                             buckets=cfg_b, store=store, seed=args.seed)
        warmup_compiles += engine.warmup()
        tag = "-".join(str(b) for b in cfg_b)
        for load in loads:
            cell = run_offered_load(engine, obs_rows, load,
                                    args.requests,
                                    swap_params=swap_params)
            hot_swaps += cell["hot_swaps"]
            cells.append(dict(cell, buckets=tag))
            rows.append((
                f"serve/{args.algo}/b{tag}/load{load:g}",
                cell["p50_ms"] * 1e3,
                f"p50_ms={cell['p50_ms']:.3f};"
                f"p99_ms={cell['p99_ms']:.3f};"
                f"throughput_rps={cell['throughput_rps']:.1f};"
                f"offered_rps={load:g};n={cell['n']};"
                f"hot_swaps={cell['hot_swaps']};"
                f"versions={cell['versions']}"))
        total_compiles += engine.compile_count
    recompiles = total_compiles - warmup_compiles
    rows.append((
        "serve/compile_flat", None,
        f"warmup_compiles={warmup_compiles};"
        f"recompiles_after_warmup={recompiles};"
        f"hot_swaps={hot_swaps};bucket_configs={len(configs)};"
        f"loads={len(loads)}"))
    path = write_bench_json(
        "serve", rows, out_dir=args.out, algo=args.algo, env=args.env,
        loads=list(loads),
        bucket_configs=[list(c) for c in configs],
        requests_per_cell=args.requests, quick=args.quick,
        train_iters=args.train_iters, source=source)
    print(json.dumps({
        "algo": args.algo, "env": args.env, "loads": list(loads),
        "bucket_configs": [list(c) for c in configs],
        "requests_per_cell": args.requests,
        "param_version": store.version,
        "warmup_compiles": warmup_compiles,
        "recompiles_after_warmup": recompiles,
        "hot_swaps": hot_swaps, "train_s": round(train_s, 1),
        "bench": os.path.basename(path), "cells": cells}))


if __name__ == "__main__":
    main()
