"""Post-SPMD HLO analysis: per-device collective-traffic parsing."""
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str):
    """Per-device bytes moved by collectives, summed per op kind, parsed
    from the post-SPMD HLO (result shapes)."""
    out = {k: 0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        head, _, rest = line.partition("=")
        m = None
        for op in _COLL_OPS:
            if re.search(rf"\b{op}(-start|-done)?\(", rest):
                m = op
                break
        if m is None or f"{m}-done(" in rest:
            continue  # count start ops once
        restype = rest.split(m)[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(restype):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[m] += nbytes
        counts[m] += 1
    out["total"] = sum(out[k] for k in _COLL_OPS)
    out["counts"] = counts
    return out



# ring-algorithm bytes-on-wire factors per result byte (16-way groups):
# all-reduce = 2(n-1)/n; all-gather/all-to-all = (n-1)/n;
# reduce-scatter ~ (n-1) (result is 1/n of the reduced input); permute = 1
WIRE_FACTORS = {"all-reduce": 1.875, "all-gather": 0.9375,
                "reduce-scatter": 15.0, "all-to-all": 0.9375,
                "collective-permute": 1.0}


def wire_bytes(kinds: dict) -> float:
    """Bytes-on-wire estimate from a per-kind result-bytes dict."""
    return sum(kinds.get(k, 0) * f for k, f in WIRE_FACTORS.items())
