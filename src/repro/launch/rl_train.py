"""Unified distributed-DRL launcher: config parsing + ``Trainer.fit``.

  PYTHONPATH=src python -m repro.launch.rl_train --algo impala \
      --env cartpole --plan "hosts=2:allreduce:bsp,workers=2:gossip:asp"

Every axis of the survey's taxonomy is one orthogonal flag, resolved by
the unified Agent/Trainer API (repro.core.agent / repro.core.trainer):

  --algo      a3c | dqn | impala | ppo    (Agent registry)
  --env       any registered env name     (env registry, `envs.make` —
                                           incl. scenario families like
                                           cartpole-rand and wrapped
                                           variants like pendulum-norm)
  --plan      hierarchical DistPlan: comma-separated mesh axes,
              outermost first, each
              ``name=size[:collective[:sync[:role]]]`` with collective
              in {ps, allreduce, gossip} (§3), sync in {bsp, asp, ssp}
              (§6) and role in {data, shard, zero3, replay} — ``shard``
              marks the ZeRO-2 learner-state sharding axis (optimizer
              state partitioned 1/size per device, gradients reduce-
              scattered, params all-gathered; allreduce only), ``zero3``
              full ZeRO-3 (params stored sharded too, all-gathered per
              use; allreduce + bsp only), ``replay`` the sharded replay
              service (ONE logical prioritized buffer over the axis,
              1/size capacity per member; allreduce + bsp only), e.g.
              ``hosts=2:allreduce:bsp,workers=4:gossip:asp``,
              ``workers=4:allreduce:bsp,shard=2:allreduce:bsp:zero3`` or
              ``workers=2:allreduce:bsp,replay=2:allreduce:bsp:replay``
  --policy    mlp | trunk — the policy network every algorithm trains:
              the house actor-critic MLP or the transformer trunk
              (networks.TrunkPolicy over configs/paper_drl.py's
              paper-drl-trunk, attention via core/attention.py's
              flash-attention dispatcher)
  --actors    elastic env-shard schedule, e.g. ``32,64,32`` — the total
              env count cycles through these values per superstep
              (ElegantRL-Podracer-style elastic actor shards)

Legacy single-axis flags remain and lower onto a 1-D plan (the two
spellings are bitwise-identical):

  --topology  ps | allreduce | gossip     == --plan "workers=N:<topo>:<sync>"
  --sync      bsp | asp | ssp
  --n-workers N

The launcher forces enough fake host devices for the plan's mesh before
jax loads. Training runs as fused supersteps: ``--superstep K``
iterations of rollout -> learner_step -> lag-ring rotate execute inside
one jitted ``lax.scan`` with a single host round-trip per dispatch;
``--unfused`` falls back to per-iteration dispatch (same numerics, for
debugging and the benchmarks/fused_superstep.py comparison).
``--pipeline`` decouples each iteration into a rollout producer and a
learner consumer joined by a device-resident trajectory queue
(repro.core.pipeline) whose depth is the staleness the plan's sync
discipline admits — the output JSON reports the resolved depth and
queue capacity.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# static mirrors of the library tuples so the parser builds without
# importing jax (XLA_FLAGS must be set first); cross-checked in main()
ALGOS = ("a3c", "dqn", "impala", "ppo")
ENV_NAMES = ("cartpole", "cartpole-rand", "cartpole-repeat", "gridworld",
             "gridworld-rand", "pendulum", "pendulum-norm",
             "pendulum-rand")
TOPOLOGY_CHOICES = ("allreduce", "ps", "gossip")
SYNC_CHOICES = ("bsp", "asp", "ssp")


def _plan_n_devices(spec: str) -> int:
    """Device count a --plan string needs — pure string math so it runs
    before jax is imported (full validation happens in DistPlan.parse).
    Rejects empty specs, duplicate axis names and non-integer sizes
    here too, naming the offending input, so the CLI errors cleanly
    without ever paying the jax import."""
    if not spec or not spec.strip():
        raise ValueError("empty --plan: expected comma-separated axes "
                         "name=size[:collective[:sync[:role]]]")
    n = 1
    seen = []
    for seg in spec.split(","):
        head = seg.strip().split(":")[0]
        if "=" not in head:
            raise ValueError(f"bad plan axis {seg!r}: expected "
                             f"name=size[:collective[:sync[:role]]]")
        name, size = head.split("=", 1)
        name = name.strip()
        if name in seen:
            raise ValueError(f"duplicate plan axis name {name!r} "
                             f"in {spec!r}")
        seen.append(name)
        try:
            n *= int(size)
        except ValueError:
            raise ValueError(f"bad plan axis {seg!r}: size {size!r} "
                             f"is not an integer") from None
    return n


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.rl_train",
        description="Unified distributed-DRL launcher (survey taxonomy "
                    "as orthogonal flags).")
    ap.add_argument("--algo", default="impala", choices=ALGOS)
    ap.add_argument("--env", default="cartpole", metavar="ENV",
                    help="registered environment, validated against the "
                         "repro.envs registry (built-ins: "
                         + ", ".join(ENV_NAMES) + "; third-party "
                         "`envs.register` entries work too)")
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--superstep", type=int, default=10,
                    help="iterations fused per jitted dispatch")
    ap.add_argument("--n-envs", type=int, default=32)
    ap.add_argument("--unroll", type=int, default=32)
    ap.add_argument("--plan", default=None, metavar="PLAN",
                    help="hierarchical DistPlan, comma-separated axes "
                         "outermost first, each name=size[:collective"
                         "[:sync[:role]]] — role `shard` marks the "
                         "ZeRO-2 learner-state sharding axis (optimizer "
                         "state lives 1/size per device; must use "
                         "allreduce), `zero3` full ZeRO-3 (params "
                         "stored sharded too, all-gathered per use; "
                         "allreduce + bsp), `replay` the sharded replay "
                         "service (one logical prioritized buffer, "
                         "1/size capacity per member; allreduce + bsp), "
                         "e.g. 'workers=4:allreduce:bsp,shard=2:"
                         "allreduce:bsp:zero3' or 'workers=2:allreduce:"
                         "bsp,replay=2:allreduce:bsp:replay'; overrides "
                         "--n-workers/--topology/--sync (which lower "
                         "onto a 1-D plan)")
    ap.add_argument("--actors", default=None, metavar="N,N,...",
                    help="elastic env-shard schedule: total env counts "
                         "cycled per superstep (each must divide across "
                         "the plan's devices)")
    ap.add_argument("--policy", default="mlp", choices=("mlp", "trunk"),
                    help="policy network: the house actor-critic MLP or "
                         "the transformer trunk (paper-drl-trunk config, "
                         "flash-attention dispatcher)")
    ap.add_argument("--n-workers", type=int, default=1)
    ap.add_argument("--topology", default="allreduce",
                    choices=TOPOLOGY_CHOICES)
    ap.add_argument("--sync", default="bsp", choices=SYNC_CHOICES)
    ap.add_argument("--policy-lag", type=int, default=0)
    ap.add_argument("--max-delay", type=int, default=4)
    ap.add_argument("--staleness-bound", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-vtrace", action="store_true",
                    help="impala only: naive targets instead of V-trace")
    ap.add_argument("--unfused", action="store_true",
                    help="per-iteration dispatch instead of fused scan")
    ap.add_argument("--pipeline", action="store_true",
                    help="decoupled actor-learner pipeline: split each "
                         "iteration into a rollout producer and learner "
                         "consumer joined by a device-resident "
                         "trajectory queue; the queue depth is what the "
                         "plan's per-axis sync discipline admits (bsp 0 "
                         "= lockstep/bitwise-fused, ssp its bound, asp "
                         "its max delay, summed over axes)")
    return ap


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    try:
        # `is not None`, not truthiness: --plan "" must be rejected as
        # an empty axis list, never silently fall back to legacy flags
        n_devices = (_plan_n_devices(args.plan) if args.plan is not None
                     else args.n_workers)
    except ValueError as e:
        ap.error(str(e))
    if n_devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{n_devices}").strip()

    import repro.envs as envs
    from repro.core import agent as agent_api
    from repro.core.distribution import DistPlan
    from repro.core.sync import MECHANISMS
    from repro.core.topology import TOPOLOGIES
    from repro.core.trainer import Trainer, TrainerConfig

    # the CLI tuples are static so the parser stays jax-free; fail loudly
    # if they ever drift from the library registries
    assert set(TOPOLOGY_CHOICES) == set(TOPOLOGIES)
    assert set(SYNC_CHOICES) == set(MECHANISMS)
    # built-in list may lag third-party registrations, never the reverse
    assert set(ENV_NAMES) <= set(envs.available()), envs.available()
    if args.algo not in agent_api.available():
        ap.error(f"--algo {args.algo} not registered; available: "
                 f"{agent_api.available()}")
    if args.env not in envs.available():
        ap.error(f"--env {args.env} not registered; available: "
                 f"{envs.available()}")

    try:
        actors = (tuple(int(n) for n in args.actors.split(","))
                  if args.actors else None)
        if args.plan is not None:
            plan = DistPlan.parse(args.plan, max_delay=args.max_delay,
                                  staleness_bound=args.staleness_bound,
                                  actors=actors)
        else:  # legacy flags lower onto the bitwise-identical 1-D plan
            plan = DistPlan.flat(args.n_workers, args.topology,
                                 args.sync, args.max_delay,
                                 args.staleness_bound, actors=actors)
    except ValueError as e:
        ap.error(str(e))

    algo_kwargs = {"policy": args.policy}
    if args.algo == "impala":
        algo_kwargs["use_vtrace"] = not args.no_vtrace
    cfg = TrainerConfig(
        algo=args.algo, iters=args.iters, superstep=args.superstep,
        n_envs=args.n_envs, unroll=args.unroll, plan=plan,
        policy_lag=args.policy_lag, seed=args.seed,
        log_every=args.log_every, pipeline=args.pipeline,
        algo_kwargs=algo_kwargs)
    env = envs.make(args.env)
    t0 = time.time()
    trainer = Trainer(env, cfg)
    _, history = trainer.fit(fused=not args.unfused)
    print(json.dumps({
        "algo": args.algo, "env": args.env, "policy": args.policy,
        "plan": plan.describe(),
        "n_devices": plan.n_devices, "fused": not args.unfused,
        # actor-learner pipeline: queue depth the plan's sync admits
        # (0 = lockstep) and the ring capacity actually allocated
        "pipeline": args.pipeline,
        "pipeline_depth": trainer.pipeline_depth,
        "pipeline_capacity": trainer.pipeline_capacity,
        "actor_shards": trainer.actor_shards[-5:],
        # ZeRO partition of the learner state (shard-role axis): axis
        # name, shard count and flat/padded/chunk element counts; None
        # on unsharded (or size-1 shard) plans
        "partition": trainer.partition,
        # sharded replay service (replay-role axis): axis name, shard
        # count and global/chunk slot counts; None when no active
        # replay axis
        "partition_replay": trainer.partition_replay,
        "wall_s": round(time.time() - t0, 1), "history": history[-5:]}))


if __name__ == "__main__":
    main()
