"""Distributed DRL launcher — the survey's taxonomy as a CLI.

  PYTHONPATH=src python -m repro.launch.rl_train --algo impala \
      --env cartpole --topology allreduce --sync bsp --iters 60

Selects: algorithm (impala/ppo/a3c/dqn), environment, topology
(§3: ps/allreduce/gossip), synchronization (§6: bsp/asp/ssp via
policy-lag), actor count. Actor rollouts and learner updates are
separate jitted programs (the Actor/Learner split of Fig. 3).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.algos import IMPALA, PPO, A3C, DQN
from repro.core.networks import MLPPolicy
from repro.core.rollout import rollout
from repro.envs import CartPole, Pendulum, GridWorld
from repro.optim import adamw, clip_by_global_norm

ENVS = {"cartpole": CartPole, "pendulum": Pendulum, "gridworld": GridWorld}


def run_impala(env, policy, iters, n_envs=32, unroll=32, lr=1e-3,
               policy_lag=1, use_vtrace=True, seed=0, log_every=10):
    """IMPALA with explicit policy-lag: actors run params `policy_lag`
    learner-updates old; V-trace corrects the off-policy gap."""
    algo = IMPALA(policy, use_vtrace=use_vtrace)
    opt = clip_by_global_norm(adamw(lr), 1.0)
    key = jax.random.PRNGKey(seed)
    params = policy.init(key)
    opt_state = opt.init(params)
    # actor params ring buffer (policy lag)
    lagged = [params] * (policy_lag + 1)
    env_state = env.reset_batch(key, n_envs)
    roll = jax.jit(lambda p, k, s: rollout(policy, p, env, k, s, unroll),
                   static_argnames=())
    history = []
    ret_acc, ret_n = 0.0, 0
    for it in range(iters):
        key = jax.random.fold_in(key, it)
        actor_params = lagged[0]           # oldest = behavior policy
        traj, env_state = roll(actor_params, key, env_state)
        boot_obs = jax.vmap(env.obs)(env_state)
        params, opt_state, loss = algo.learner_step(
            params, opt_state, traj, boot_obs, opt)
        lagged = lagged[1:] + [params]
        ep_rew = float(traj["reward"].sum() / jnp.maximum(
            traj["done"].sum(), 1))
        ret_acc += ep_rew
        ret_n += 1
        if it % log_every == 0 or it == iters - 1:
            history.append({"iter": it, "loss": round(float(loss), 4),
                            "mean_episode_return":
                                round(ret_acc / ret_n, 2)})
            ret_acc, ret_n = 0.0, 0
    return params, history


def run_ppo(env, policy, iters, n_envs=16, unroll=64, lr=3e-4, seed=0,
            log_every=5):
    algo = PPO(policy)
    opt = clip_by_global_norm(adamw(lr), 0.5)
    key = jax.random.PRNGKey(seed)
    params = policy.init(key)
    opt_state = opt.init(params)
    env_state = env.reset_batch(key, n_envs)
    roll = jax.jit(lambda p, k, s: rollout(policy, p, env, k, s, unroll))
    history = []
    for it in range(iters):
        key = jax.random.fold_in(key, it)
        traj, env_state = roll(params, key, env_state)
        boot_obs = jax.vmap(env.obs)(env_state)
        batch = algo.make_batch(params, traj, boot_obs)
        params, opt_state, loss = algo.update(params, opt_state, batch,
                                              key, opt)
        ep = float(traj["reward"].sum() / jnp.maximum(
            traj["done"].sum(), 1))
        if it % log_every == 0 or it == iters - 1:
            history.append({"iter": it, "loss": round(float(loss), 4),
                            "mean_episode_return": round(ep, 2)})
    return params, history


def run_dqn(env, iters, n_envs=16, lr=1e-3, seed=0, log_every=20,
            prioritized=True):
    algo = DQN(env.obs_dim, env.n_actions, prioritized=prioritized,
               replay_capacity=20000)
    opt = adamw(lr)
    key = jax.random.PRNGKey(seed)
    params = algo.init(key)
    opt_state = opt.init(params["online"])
    ex = {"obs": jnp.zeros((env.obs_dim,)),
          "action": jnp.zeros((), jnp.int32),
          "reward": jnp.zeros(()),
          "next_obs": jnp.zeros((env.obs_dim,)),
          "done": jnp.zeros((), bool)}
    rstate = algo.replay.init(ex)
    env_state = env.reset_batch(key, n_envs)

    @jax.jit
    def actor_step(params, env_state, key, eps):
        obs = jax.vmap(env.obs)(env_state)
        a = algo.act(params, obs, key, eps)
        env_state, next_obs, r, d = env.step_autoreset(env_state, a, key)
        batch = {"obs": obs, "action": a, "reward": r,
                 "next_obs": next_obs, "done": d}
        return env_state, batch, r

    history = []
    rew_acc = 0.0
    for it in range(iters):
        key = jax.random.fold_in(key, it)
        eps = max(0.05, 1.0 - it / (0.6 * iters))
        env_state, batch, r = actor_step(params, env_state, key, eps)
        rstate = algo.replay.add_batch(rstate, batch)
        if it > 50:
            params, opt_state, rstate, loss = algo.learner_step(
                params, opt_state, rstate, key, opt)
        rew_acc += float(r.mean())
        if it % log_every == 0 or it == iters - 1:
            history.append({"iter": it,
                            "mean_reward": round(rew_acc / log_every, 3)})
            rew_acc = 0.0
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="impala",
                    choices=("impala", "ppo", "dqn"))
    ap.add_argument("--env", default="cartpole", choices=list(ENVS))
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--n-envs", type=int, default=32)
    ap.add_argument("--policy-lag", type=int, default=1)
    ap.add_argument("--no-vtrace", action="store_true")
    args = ap.parse_args()
    env = ENVS[args.env]()
    t0 = time.time()
    if args.algo == "dqn":
        _, history = run_dqn(env, args.iters, args.n_envs)
    else:
        policy = MLPPolicy(env.obs_dim, env.n_actions, env.act_dim)
        runner = run_impala if args.algo == "impala" else run_ppo
        kwargs = {}
        if args.algo == "impala":
            kwargs = {"policy_lag": args.policy_lag,
                      "use_vtrace": not args.no_vtrace}
        _, history = runner(env, policy, args.iters, args.n_envs,
                            **kwargs)
    print(json.dumps({"algo": args.algo, "env": args.env,
                      "wall_s": round(time.time() - t0, 1),
                      "history": history[-5:]}))


if __name__ == "__main__":
    main()
