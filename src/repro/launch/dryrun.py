"""Multi-pod dry-run: lower + compile every (arch × shape × mesh)
combination against the production mesh and extract the roofline terms.

MUST set the host-device count before ANY other import (jax locks the
device count on first init) — hence the first two lines.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k [--multi-pod] [--fsdp] [--param-dtype bfloat16]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full 40-pair sweep
Results are appended as JSON under experiments/dryrun/.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config
from repro.launch.hlo_analysis import collective_bytes
from repro.launch import analytic
from repro.launch.mesh import (make_production_mesh, PEAK_FLOPS_BF16,
                               HBM_BW, ICI_BW)
from repro.launch.sharding import (shard_params, batch_sharding,
                                   cache_sharding)
from repro.models import build_model
from repro.models.model import ModelOpts
from repro.optim import adamw
from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../experiments/dryrun")

# per-arch memory-fit decisions (DESIGN.md §5): big models train with
# bf16 params + ZeRO-3 over the data axis.
ARCH_OVERRIDES = {
    "llama4-maverick-400b-a17b": {"param_dtype": "bfloat16", "fsdp": True},
    "jamba-v0.1-52b": {"param_dtype": "bfloat16", "fsdp": True},
    "deepseek-moe-16b": {"fsdp": True},
    "minicpm3-4b": {"fsdp": True},
}

def _cast_struct(struct, dtype):
    def cast(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(leaf.shape, jnp.dtype(dtype))
        return leaf
    return jax.tree_util.tree_map(cast, struct)


def _replicated_like(mesh, struct):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), struct)


def build_case(arch: str, shape_name: str, mesh, param_dtype="float32",
               fsdp=False, model_opts=None, policy="baseline"):
    """Returns (fn, arg_structs, in_shardings, meta)."""
    cfg = get_config(arch)
    shape_cfg = SHAPES[shape_name]
    opts = model_opts or ModelOpts(dtype="bfloat16", remat=True)
    model = build_model(cfg, opts)
    specs = model.input_specs(shape_cfg)

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_struct = _cast_struct(params_struct, param_dtype)
    params_shard = shard_params(params_struct, mesh, fsdp=fsdp,
                                policy=policy)

    if shape_cfg.mode == "train":
        optimizer = adamw(1e-4)
        opt_struct = jax.eval_shape(optimizer.init, params_struct)
        opt_shard = {"step": NamedSharding(mesh, P()),
                     "m": shard_params(opt_struct["m"], mesh, fsdp=fsdp,
                                       policy=policy),
                     "v": shard_params(opt_struct["v"], mesh, fsdp=fsdp,
                                       policy=policy)}
        batch_struct = specs["batch"]
        b_shard = batch_sharding(mesh, batch_struct, policy)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            params, opt_state = optimizer.apply(params, opt_state, grads)
            return params, opt_state, loss

        return (train_step, (params_struct, opt_struct, batch_struct),
                (params_shard, opt_shard, b_shard),
                {"model": model, "cfg": cfg, "shape": shape_cfg})

    if shape_cfg.mode == "prefill":
        tok_struct = specs["tokens"]
        args = [tok_struct]
        shards = [batch_sharding(mesh, tok_struct, policy)]
        if "frontend" in specs:
            args.append(specs["frontend"])
            shards.append(batch_sharding(mesh, specs["frontend"],
                                          policy))

        def prefill_step(params, tokens, *rest):
            fe = rest[0] if rest else None
            return model.prefill(params, tokens, fe)

        return (prefill_step, (params_struct, *args),
                (params_shard, *shards),
                {"model": model, "cfg": cfg, "shape": shape_cfg})

    # decode
    tok = specs["token"]
    cache = specs["cache"]
    pos = specs["pos"]
    cache_shard = cache_sharding(mesh, cache, shape_cfg.global_batch)

    def serve_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    return (serve_step, (params_struct, tok, cache, pos),
            (params_shard, batch_sharding(mesh, tok), cache_shard,
             NamedSharding(mesh, P())),
            {"model": model, "cfg": cfg, "shape": shape_cfg})


def stack_probe_collectives(model, shape_cfg, mesh, params_struct,
                            fsdp, param_dtype, policy="baseline"):
    """Per-device collective bytes of ONE scanned super-block, lowered
    standalone under the same shardings. The full program's HLO counts
    the scan body once; total collectives = top-level + repeats × probe.
    (Gradient is taken wrt activations only — the data-axis param-grad
    all-reduce happens once at top level in the real program and is
    already counted there.)"""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import blocks as blk

    if model.repeats < 1 or "stack" not in params_struct:
        return {"total": 0}, 0
    cfg = model.cfg
    sds = jax.ShapeDtypeStruct
    sb_struct = jax.tree_util.tree_map(
        lambda a: sds(a.shape[1:], a.dtype), params_struct["stack"])
    sb_shard = shard_params(sb_struct, mesh, fsdp=fsdp, policy=policy)
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    if cfg.frontend == "vision_stub" and shape_cfg.mode != "decode":
        S = S + cfg.frontend_tokens
    baxes = (("pod", "data", "model") if policy == "pure_dp"
             else ("pod", "data"))
    bsz = 1
    for a in baxes:
        bsz *= mesh.shape.get(a, 1)
    bx = tuple(a for a in baxes if a in mesh.axis_names)
    bleaf = bx if (B % bsz == 0 and B >= bsz) else None
    act_dt = model.opts.jdtype
    mode = shape_cfg.mode

    if mode in ("train", "prefill"):
        x_struct = sds((B, S, cfg.d_model), act_dt)
        x_shard = NamedSharding(mesh, P(bleaf, None, None))

        def probe(sbp, x):
            def f(x):
                y = x
                for t in range(model.period):
                    y, _, aux = blk.apply_block_seq(
                        cfg, sbp[f"t{t}"], model.stack_specs[t][0],
                        model.stack_specs[t][1], y, jnp.int32(0),
                        model.attn_opts,
                        cache_capacity=(0 if mode == "train" else S + 1),
                        gelu_mlp=model.gelu_mlp)
                return y.astype(jnp.float32).mean()
            if mode == "train":
                return jax.grad(f)(x)
            return f(x)

        args = (sb_struct, x_struct)
        shards = (sb_shard, x_shard)
    else:  # decode
        x_struct = sds((B, 1, cfg.d_model), act_dt)
        x_shard = NamedSharding(mesh, P(bleaf, None, None))
        sb_cache = jax.eval_shape(
            lambda: {f"t{t}": blk.init_cache(
                cfg, model.stack_specs[t][0], B, S + 1, act_dt,
                has_cross=model.has_cross, enc_tokens=cfg.enc_tokens)
                for t in range(model.period)})
        cshard = cache_sharding(mesh, sb_cache, B)

        def probe(sbp, x, cache):
            y = x
            for t in range(model.period):
                y, _, _ = blk.apply_block_decode(
                    cfg, sbp[f"t{t}"], model.stack_specs[t][0],
                    model.stack_specs[t][1], y, cache[f"t{t}"],
                    jnp.int32(S), model.attn_opts,
                    gelu_mlp=model.gelu_mlp)
            return y

        args = (sb_struct, x_struct, sb_cache)
        shards = (sb_shard, x_shard, cshard)

    with mesh:
        lowered = jax.jit(probe, in_shardings=shards).lower(*args)
        compiled = lowered.compile()
    return collective_bytes(compiled.as_text()), model.repeats


def model_flops(cfg, shape_cfg):
    """6·N·D (dense) / 6·N_active·D (MoE) — the useful-FLOPs yardstick."""
    n_active = cfg.param_count(active_only=True)
    if shape_cfg.mode == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6 * n_active * tokens
    if shape_cfg.mode == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2 * n_active * tokens
    return 2 * n_active * shape_cfg.global_batch  # decode: 1 token


def applicable(cfg, shape_name):
    if shape_name == "long_500k" and not cfg.subquadratic():
        return False, "pure full-attention arch: 500k decode skipped " \
                      "(DESIGN.md §6)"
    return True, ""


def dryrun_one(arch, shape_name, *, multi_pod=False, mesh_shape=None,
               param_dtype=None, fsdp=None, model_opts=None, save=True,
               tag="", policy="baseline"):
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi_pod" if multi_pod else "single_pod", "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=why)
        _save(rec, save)
        return rec
    ov = ARCH_OVERRIDES.get(arch, {})
    param_dtype = param_dtype or ov.get("param_dtype", "float32")
    fsdp = ov.get("fsdp", False) if fsdp is None else fsdp
    rec.update(param_dtype=param_dtype, fsdp=fsdp, policy=policy)
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    t0 = time.time()
    try:
        fn, structs, shardings, meta = build_case(
            arch, shape_name, mesh, param_dtype, fsdp, model_opts,
            policy=policy)
        with mesh:
            lowered = jax.jit(fn, in_shardings=shardings).lower(*structs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        try:
            mem = compiled.memory_analysis()
        except Exception:
            mem = None
        coll_top = collective_bytes(compiled.as_text())
        try:
            coll_probe, repeats = stack_probe_collectives(
                meta["model"], meta["shape"], mesh, structs[0], fsdp,
                param_dtype, policy=policy)
        except Exception as e:
            coll_probe, repeats = {"total": 0}, 0
            rec["probe_error"] = f"{type(e).__name__}: {e}"
        # scan correction: full HLO counts the scan body once
        coll_total = coll_top["total"] + max(repeats - 1, 0) \
            * coll_probe["total"]
        chips = mesh.devices.size
        hlo_flops = float(ca.get("flops", 0.0)) if ca else 0.0
        hlo_bytes = float(ca.get("bytes accessed", 0.0)) if ca else 0.0
        mf = model_flops(meta["cfg"], meta["shape"])
        a_flops = analytic.step_flops(
            meta["cfg"], meta["shape"],
            remat=meta["model"].opts.remat) / chips
        eff_model_axis = (1 if policy == "pure_dp"
                          else mesh.shape.get("model", 1))
        a_bytes = analytic.step_hbm_bytes(
            meta["cfg"], meta["shape"], chips,
            param_bytes=jnp.dtype(param_dtype).itemsize,
            fsdp=fsdp, model_axis=eff_model_axis,
            data_axis=mesh.shape.get("data", 1))
        rec.update(
            status="ok", chips=chips,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            # analytic (scan-corrected) roofline numerators, per chip:
            flops_per_chip=a_flops, hbm_bytes_per_chip=a_bytes,
            # HLO cross-checks (scan bodies counted once — see analytic.py)
            hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
            collective_bytes=coll_top,
            collective_probe_bytes=coll_probe, stack_repeats=repeats,
            collective_bytes_corrected=coll_total,
            model_flops=mf,
            useful_flops_ratio=(mf / (a_flops * chips)
                                if a_flops else None),
            compute_term_s=a_flops / PEAK_FLOPS_BF16,
            memory_term_s=a_bytes / HBM_BW,
            collective_term_s=coll_total / ICI_BW,
            params=meta["cfg"].param_count(),
            params_active=meta["cfg"].param_count(active_only=True),
        )
        terms = {"compute": rec["compute_term_s"],
                 "memory": rec["memory_term_s"],
                 "collective": rec["collective_term_s"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                try:
                    rec[f"mem_{k}"] = int(getattr(mem, k))
                except Exception:
                    pass
    except Exception as e:  # record the failure — these are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    _save(rec, save)
    return rec


def _save(rec, save):
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
    if rec.get("tag"):
        name += f"_{rec['tag']}"
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--fsdp", action="store_true", default=None)
    ap.add_argument("--mesh-shape", default=None,
                    help="comma ints, e.g. 4,4 (debug)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--policy", default="baseline")
    args = ap.parse_args()
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split(","))
                  if args.mesh_shape else None)

    if args.all:
        from repro.configs import list_archs
        archs = [a for a in list_archs() if a != "paper-drl-trunk"]
        cases = [(a, s) for a in archs for s in SHAPES]
    else:
        cases = [(args.arch, args.shape)]
    for arch, shape in cases:
        rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                         mesh_shape=mesh_shape,
                         param_dtype=args.param_dtype, fsdp=args.fsdp,
                         tag=args.tag, policy=args.policy)
        keys = ("status", "compile_s", "hlo_flops", "compute_term_s",
                "memory_term_s", "collective_term_s", "bottleneck",
                "reason", "error")
        print(json.dumps({"arch": arch, "shape": shape,
                          **{k: rec[k] for k in keys if k in rec}}))


if __name__ == "__main__":
    main()
