"""Production mesh builders (TPU v5e pods).

Functions, not module-level constants, so importing this module never
touches jax device state. Hardware constants for the roofline are here
too (single source of truth).
"""
import jax

# TPU v5e per-chip constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """16x16 = 256 chips/pod; multi_pod adds a 2-pod leading axis.
    `shape` overrides for scaled-down debugging (same axis names)."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (("pod", "data", "model") if len(shape) == 3
            else ("data", "model"))
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_worker_mesh(n_workers: int):
    """1-D worker mesh for the DRL topology/sync experiments."""
    return jax.make_mesh((n_workers,), ("workers",))


def batch_axes(mesh):
    """Mesh axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_chips(mesh):
    return mesh.devices.size
