"""Batched serving driver (actor side): prefill a batch of prompts, then
step the decoder with a KV cache — the survey's SEED-style centralized
inference path (§3.3: Learner-side inference, actors receive actions).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.models.model import ModelOpts


def serve(arch="smollm-360m", reduced=True, batch=4, prompt_len=32,
          gen_len=16, temperature=1.0, seed=0, dtype="float32"):
    model = build_model(arch, ModelOpts(dtype=dtype, remat=False),
                        reduced=reduced)
    cfg = model.cfg
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    fe = None
    if cfg.frontend == "vision_stub":
        fe = 0.02 * jnp.ones((batch, cfg.frontend_tokens,
                              cfg.frontend_dim or cfg.d_model))
    elif cfg.frontend == "audio_stub":
        fe = 0.02 * jnp.ones((batch, cfg.enc_tokens, cfg.d_model))

    prefill = jax.jit(lambda p, t, f: model.prefill(
        p, t, f, cache_capacity=prompt_len + gen_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, prompts, fe)
    t_prefill = time.time() - t0
    n_prefix = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0

    tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for i in range(gen_len):
        pos = jnp.int32(prompt_len + n_prefix + i)
        logits, cache = decode(params, tok, cache, pos)
        key = jax.random.fold_in(key, i)
        if temperature > 0:
            tok = jax.random.categorical(
                key, logits[:, -1] / temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(tokens, axis=1)
    return {"arch": arch, "batch": batch,
            "prefill_s": round(t_prefill, 3),
            "decode_tok_per_s": round(batch * gen_len / t_decode, 1),
            "generated_shape": list(gen.shape),
            "sample": gen[0, :8].tolist()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    print(json.dumps(serve(args.arch, args.reduced, args.batch,
                           args.prompt_len, args.gen_len)))


if __name__ == "__main__":
    main()
