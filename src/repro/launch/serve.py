"""Serving launchers (actor side) — two traffic surfaces, one module:

  * **LM stub** (default): prefill a batch of prompts, then step the
    decoder with a KV cache — the survey's SEED-style centralized
    inference path (§3.3: learner-side inference, actors receive
    actions). Compile time is excluded: a warmup prefill+decode runs
    first (reported as `warmup_s`), so `prefill_s` and
    `decode_tok_per_s` are steady-state numbers.

  * **Policy serving** (`policy` subcommand): forwards to
    repro.launch.serve_policy — the bucketed micro-batching /
    hot-swap engine over repro.core.serving, with the offered-load
    p50/p99 benchmark (BENCH_serve.json).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 32 --gen-len 16
  PYTHONPATH=src python -m repro.launch.serve policy --algo ppo --quick
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.models.model import ModelOpts


def serve(arch="smollm-360m", reduced=True, batch=4, prompt_len=32,
          gen_len=16, temperature=1.0, seed=0, dtype="float32"):
    model = build_model(arch, ModelOpts(dtype=dtype, remat=False),
                        reduced=reduced)
    cfg = model.cfg
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    fe = None
    if cfg.frontend == "vision_stub":
        fe = 0.02 * jnp.ones((batch, cfg.frontend_tokens,
                              cfg.frontend_dim or cfg.d_model))
    elif cfg.frontend == "audio_stub":
        fe = 0.02 * jnp.ones((batch, cfg.enc_tokens, cfg.d_model))

    prefill = jax.jit(lambda p, t, f: model.prefill(
        p, t, f, cache_capacity=prompt_len + gen_len))
    decode = jax.jit(model.decode_step)
    n_prefix = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0

    # warmup: compile prefill AND decode before anything is timed, so
    # prefill_s / decode_tok_per_s are steady-state serving numbers
    # (the compile cost is real but paid once — reported separately)
    t0 = time.time()
    logits_w, cache_w = prefill(params, prompts, fe)
    tok_w = jnp.argmax(logits_w[:, -1], axis=-1)[:, None]
    jax.block_until_ready(
        decode(params, tok_w, cache_w, jnp.int32(prompt_len + n_prefix)))
    t_warmup = time.time() - t0

    t0 = time.time()
    logits, cache = prefill(params, prompts, fe)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for i in range(gen_len):
        pos = jnp.int32(prompt_len + n_prefix + i)
        logits, cache = decode(params, tok, cache, pos)
        key = jax.random.fold_in(key, i)
        if temperature > 0:
            tok = jax.random.categorical(
                key, logits[:, -1] / temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(tokens, axis=1)
    return {"arch": arch, "batch": batch,
            "warmup_s": round(t_warmup, 3),
            "prefill_s": round(t_prefill, 3),
            "decode_tok_per_s": round(batch * gen_len / t_decode, 1),
            "generated_shape": list(gen.shape),
            "sample": gen[0, :8].tolist()}


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if argv and argv[0] == "policy":
        # bucketed micro-batching policy serving lives in its own
        # launcher; this is the one front door for both surfaces
        from repro.launch.serve_policy import main as policy_main
        return policy_main(argv[1:])
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve",
        description="LM-stub serving benchmark; use the `policy` "
                    "subcommand for batched policy serving "
                    "(repro.launch.serve_policy).")
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args(argv)
    print(json.dumps(serve(args.arch, args.reduced, args.batch,
                           args.prompt_len, args.gen_len)))


if __name__ == "__main__":
    main()
