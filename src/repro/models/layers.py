"""Shared primitive layers: norms, RoPE, SwiGLU MLP, embeddings, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale=None):
    """Truncated-normal fan-in init, stored f32."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale)


# -- norms ------------------------------------------------------------------

def init_norm(cfg, key=None):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                "bias": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32)}


def apply_norm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"] + params["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return y.astype(x.dtype)


# -- rotary embeddings ------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                      # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLP --------------------------------------------------------------------

def init_mlp(cfg, key, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": dense_init(k1, (cfg.d_model, d_ff)),
            "wg": dense_init(k2, (cfg.d_model, d_ff)),
            "wo": dense_init(k3, (d_ff, cfg.d_model))}


def apply_mlp(params, x):
    """SwiGLU."""
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype))
    g = jnp.einsum("...d,df->...f", x, params["wg"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(x.dtype))


def init_mlp_gelu(cfg, key, d_ff=None):
    """2-matrix GELU MLP (whisper-style)."""
    d_ff = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, (cfg.d_model, d_ff)),
            "wo": dense_init(k2, (d_ff, cfg.d_model))}


def apply_mlp_gelu(params, x):
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(h),
                      params["wo"].astype(x.dtype))


# -- embeddings -------------------------------------------------------------

def init_embed(cfg, key):
    # d^-0.5 keeps tied-unembedding logits O(1) (input side is rescaled
    # by sqrt(d) for tied/gemma-style configs)
    p = {"tok": dense_init(key, (cfg.vocab, cfg.d_model),
                           scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(jax.random.fold_in(key, 1),
                                  (cfg.d_model, cfg.vocab))
    return p


def embed_tokens(params, tokens, cfg, dtype):
    x = jnp.take(params["tok"], tokens, axis=0).astype(dtype)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)  # gemma-style scaling
    return x


def unembed(params, x, cfg):
    if cfg.tie_embeddings:
        w = params["tok"].astype(x.dtype).T
    else:
        w = params["unembed"].astype(x.dtype)
    return jnp.einsum("...d,dv->...v", x, w)
