"""LanguageModel: assembles blocks into the full architecture.

Layer-stack decomposition for compile-time economy: the layer list is
factored into  [prefix | R × super-block | tail]  where the super-block is
the smallest repeating (kind, is_moe) period — the R repeats lower as a
single `lax.scan` (one HLO body regardless of depth). Heterogeneous
interleaves (gemma3 5local:1global, jamba 7mamba:1attn, llama4
dense/MoE alternation) are super-blocks.

Execution modes: `forward` (train), `prefill` (emits KV/recurrent cache),
`decode_step` (one token against the cache). Audio (whisper) runs an
encoder over stub frame embeddings with decoder cross-attention; VLM
(paligemma) prepends projected stub patch embeddings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RWKV, MAMBA
from repro.models import blocks as blk
from repro.models.attention import AttnOpts
from repro.models.layers import (init_norm, apply_norm, init_embed,
                                 embed_tokens, unembed, dense_init)


@jax.custom_vjp
def _sequence_barrier(x):
    """Identity with an XLA optimization barrier in both the forward
    and backward pass. `jax.lax.optimization_barrier` has no AD rule,
    but the layer-wise ZeRO-3 loop needs one inside `value_and_grad`:
    without it XLA hoists every block's all-gather ahead of the loop
    and re-creates the whole-vector live peak the per-block partition
    exists to avoid."""
    return jax.lax.optimization_barrier(x)


def _sequence_barrier_fwd(x):
    return _sequence_barrier(x), None


def _sequence_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_sequence_barrier.defvjp(_sequence_barrier_fwd, _sequence_barrier_bwd)


@dataclasses.dataclass(frozen=True)
class ModelOpts:
    dtype: str = "bfloat16"
    remat: bool = True
    use_kernels: bool = False
    block_k: int = 512
    n_q_chunks: int = 8
    moe_local_dispatch: bool = False
    # mesh axes the batch dim of activations is sharded over; when set,
    # a with_sharding_constraint re-anchors the (B,S,d) carry inside the
    # layer scan — XLA otherwise loses the sharding in the rematted
    # backward body and replicates the whole carry (§Perf finding)
    act_batch_axes: tuple = ()

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _lcm(a, b):
    return a * b // math.gcd(a, b)


class LanguageModel:
    def __init__(self, cfg: ModelConfig, opts: ModelOpts = ModelOpts()):
        self.cfg = cfg
        self.opts = opts
        self.attn_opts = AttnOpts(dtype=opts.jdtype, block_k=opts.block_k,
                                  n_q_chunks=opts.n_q_chunks,
                                  use_kernels=opts.use_kernels,
                                  moe_local=opts.moe_local_dispatch)
        self.gelu_mlp = cfg.family == "audio"
        self.has_cross = cfg.enc_layers > 0
        pat = cfg.pattern()
        self.specs = [(pat[i], cfg.is_moe_layer(i))
                      for i in range(cfg.n_layers)]
        # stack decomposition
        self.prefix_len = cfg.moe.first_dense if cfg.moe else 0
        period = len(cfg.layer_pattern)
        if cfg.moe:
            period = _lcm(period, cfg.moe.every)
        rem = cfg.n_layers - self.prefix_len
        self.period = period
        self.repeats = rem // period
        self.tail_len = rem - self.repeats * period
        self.stack_specs = self.specs[self.prefix_len:
                                      self.prefix_len + period]

    # ------------------------------------------------------------- init
    def init(self, key) -> dict:
        cfg = self.cfg
        kE, kP, kS, kT, kN, kEnc, kProj = jax.random.split(key, 7)
        params = {"embed": init_embed(cfg, kE),
                  "final_norm": init_norm(cfg)}
        if self.prefix_len:
            params["prefix"] = [
                blk.init_block(cfg, jax.random.fold_in(kP, i),
                               self.specs[i][0], self.specs[i][1],
                               self.has_cross, self.gelu_mlp)
                for i in range(self.prefix_len)]

        def init_superblock(k):
            return {f"t{t}": blk.init_block(
                cfg, jax.random.fold_in(k, t), self.stack_specs[t][0],
                self.stack_specs[t][1], self.has_cross, self.gelu_mlp)
                for t in range(self.period)}

        if self.repeats:
            params["stack"] = jax.vmap(init_superblock)(
                jax.random.split(kS, self.repeats))
        if self.tail_len:
            base = self.prefix_len + self.repeats * self.period
            params["tail"] = [
                blk.init_block(cfg, jax.random.fold_in(kT, i),
                               self.specs[base + i][0],
                               self.specs[base + i][1],
                               self.has_cross, self.gelu_mlp)
                for i in range(self.tail_len)]
        if cfg.enc_layers:
            def init_enc_block(k):
                return blk.init_block(cfg, k, "attn", False, False,
                                      gelu_mlp=True)
            params["enc"] = {
                "stack": jax.vmap(init_enc_block)(
                    jax.random.split(kEnc, cfg.enc_layers)),
                "final_norm": init_norm(cfg),
                "pos": 0.02 * jax.random.normal(
                    jax.random.fold_in(kEnc, 99),
                    (cfg.enc_tokens, cfg.d_model), jnp.float32),
            }
        if cfg.frontend == "vision_stub":
            fd = cfg.frontend_dim or cfg.d_model
            params["projector"] = dense_init(kProj, (fd, cfg.d_model))
        return params

    # --------------------------------------------------------- encoder
    def encode(self, params, frames):
        """Whisper encoder over stub frame embeddings (B, Te, d)."""
        cfg = self.cfg
        x = frames.astype(self.opts.jdtype) + \
            params["enc"]["pos"].astype(self.opts.jdtype)

        def body(x, layer_params):
            y, _, _ = blk.apply_block_seq(
                cfg, layer_params, "attn", False, x, jnp.int32(0),
                self.attn_opts, gelu_mlp=True, causal=False)
            return y, None

        x, _ = jax.lax.scan(body, x, params["enc"]["stack"])
        return apply_norm(params["enc"]["final_norm"], x)

    # ------------------------------------------------------ seq runner
    def _run_seq(self, params, x, pos0, enc_out, cache_capacity):
        cfg, opts = self.cfg, self.attn_opts
        aux = jnp.zeros((), jnp.float32)
        caches = {}

        def one(params_i, x, spec, cap):
            return blk.apply_block_seq(
                cfg, params_i, spec[0], spec[1], x, pos0, opts,
                cache_capacity=cap, enc_out=enc_out,
                gelu_mlp=self.gelu_mlp)

        if self.prefix_len:
            pc = []
            for i in range(self.prefix_len):
                x, c, a = one(params["prefix"][i], x, self.specs[i],
                              cache_capacity)
                pc.append(c)
                aux = aux + a
            caches["prefix"] = pc

        if self.repeats:
            def sb_body(carry, sb_params):
                x, aux = carry
                if self.opts.act_batch_axes:
                    from jax.sharding import PartitionSpec
                    x = jax.lax.with_sharding_constraint(
                        x, PartitionSpec(tuple(self.opts.act_batch_axes),
                                         None, None))
                cs = {}
                for t in range(self.period):
                    x, c, a = one(sb_params[f"t{t}"], x,
                                  self.stack_specs[t], cache_capacity)
                    cs[f"t{t}"] = c
                    aux = aux + a
                return (x, aux), cs

            body = sb_body
            if self.opts.remat and not cache_capacity:
                body = jax.checkpoint(sb_body, prevent_cse=False)
            stack = params["stack"]
            if isinstance(stack, (list, tuple)):
                # layer-wise ZeRO-3: the superblocks arrive as a list
                # of per-block pytrees (each typically the all-gather
                # of one 1/N chunk). Run them unrolled so each gather
                # is consumed and dropped before the next block's
                # params materialize; the optimization barrier ties
                # block r's params to block r-1's output, so XLA
                # cannot hoist every gather ahead of the loop and
                # re-create the whole-vector peak.
                carry, sc = (x, aux), []
                for r, sb_params in enumerate(stack):
                    if r:
                        sb_params, carry = _sequence_barrier(
                            (sb_params, carry))
                    carry, cs = body(carry, sb_params)
                    sc.append(cs)
                x, aux = carry
                if cache_capacity:
                    caches["stack"] = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs), *sc)
            else:
                (x, aux), sc = jax.lax.scan(body, (x, aux), stack)
                if cache_capacity:
                    caches["stack"] = sc

        if self.tail_len:
            base = self.prefix_len + self.repeats * self.period
            tc = []
            for i in range(self.tail_len):
                x, c, a = one(params["tail"][i], x, self.specs[base + i],
                              cache_capacity)
                tc.append(c)
                aux = aux + a
            caches["tail"] = tc
        return x, caches, aux

    # ------------------------------------------------------- frontends
    def _prepend_frontend(self, params, x, frontend):
        """VLM: project + prepend patch embeddings. Returns (x, n_prefix)."""
        cfg = self.cfg
        if cfg.frontend == "vision_stub":
            fe = jnp.einsum("bpd,de->bpe", frontend.astype(self.opts.jdtype),
                            params["projector"].astype(self.opts.jdtype))
            return jnp.concatenate([fe, x], axis=1), cfg.frontend_tokens
        return x, 0

    # ------------------------------------------------------------ train
    def forward(self, params, tokens, frontend=None):
        """Train-mode forward. Returns (logits, aux_loss)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg, self.opts.jdtype)
        enc_out = None
        if cfg.enc_layers:
            enc_out = self.encode(params, frontend)
        n_prefix = 0
        if cfg.frontend == "vision_stub":
            x, n_prefix = self._prepend_frontend(params, x, frontend)
        x, _, aux = self._run_seq(params, x, jnp.int32(0), enc_out, 0)
        x = apply_norm(params["final_norm"], x)
        logits = unembed(params["embed"], x, cfg)
        if n_prefix:
            logits = logits[:, n_prefix:]
        return logits, aux

    def loss(self, params, batch):
        """Next-token cross-entropy (+ MoE aux). batch: {'tokens': (B,S),
        optional 'frontend'}."""
        tokens = batch["tokens"]
        logits, aux = self.forward(params, tokens[:, :-1],
                                   batch.get("frontend"))
        targets = tokens[:, 1:]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        picked = jnp.take_along_axis(
            logits.astype(jnp.float32), targets[..., None],
            axis=-1)[..., 0]
        ce = jnp.mean(lse - picked)
        return ce + aux, {"ce": ce, "aux": aux}

    # ---------------------------------------------------------- prefill
    def prefill(self, params, tokens, frontend=None,
                cache_capacity: Optional[int] = None):
        """Returns (last-token logits, cache)."""
        cfg = self.cfg
        S = tokens.shape[1]
        cap = cache_capacity or S + 1  # one free slot for the next token
        x = embed_tokens(params["embed"], tokens, cfg, self.opts.jdtype)
        enc_out = None
        if cfg.enc_layers:
            enc_out = self.encode(params, frontend)
        n_prefix = 0
        if cfg.frontend == "vision_stub":
            x, n_prefix = self._prepend_frontend(params, x, frontend)
            cap = cap + n_prefix
        x, caches, _ = self._run_seq(params, x, jnp.int32(0), enc_out, cap)
        x = apply_norm(params["final_norm"], x[:, -1:])
        logits = unembed(params["embed"], x, cfg)
        return logits, caches

    # ------------------------------------------------------ decode step
    def decode_step(self, params, token, cache, pos):
        """token: (B,1) int32; pos: scalar int32 — absolute position of
        this token (for the assigned decode shapes, pos == context len and
        every cache is full). Returns (logits (B,1,V), new cache)."""
        cfg, opts = self.cfg, self.attn_opts
        x = embed_tokens(params["embed"], token, cfg, self.opts.jdtype)
        aux = jnp.zeros((), jnp.float32)
        new_cache = {}

        def one(params_i, x, spec, c):
            return blk.apply_block_decode(cfg, params_i, spec[0], spec[1],
                                          x, c, pos, opts,
                                          gelu_mlp=self.gelu_mlp)

        if self.prefix_len:
            pc = []
            for i in range(self.prefix_len):
                x, c, a = one(params["prefix"][i], x, self.specs[i],
                              cache["prefix"][i])
                pc.append(c)
            new_cache["prefix"] = pc

        if self.repeats:
            def sb_body(carry, xs):
                x = carry
                sbp, sbc = xs
                cs = {}
                for t in range(self.period):
                    x, c, _ = one(sbp[f"t{t}"], x, self.stack_specs[t],
                                  sbc[f"t{t}"])
                    cs[f"t{t}"] = c
                return x, cs

            x, sc = jax.lax.scan(sb_body, x,
                                 (params["stack"], cache["stack"]))
            new_cache["stack"] = sc

        if self.tail_len:
            base = self.prefix_len + self.repeats * self.period
            tc = []
            for i in range(self.tail_len):
                x, c, a = one(params["tail"][i], x, self.specs[base + i],
                              cache["tail"][i])
                tc.append(c)
            new_cache["tail"] = tc

        x = apply_norm(params["final_norm"], x)
        logits = unembed(params["embed"], x, cfg)
        return logits, new_cache

    # ---------------------------------------------------- cache builder
    def make_cache(self, batch: int, capacity: int):
        """Zero cache with the exact structure decode_step expects."""
        cfg = self.cfg
        dt = self.opts.jdtype

        def entry(spec):
            return blk.init_cache(cfg, spec[0], batch, capacity, dt,
                                  has_cross=self.has_cross,
                                  enc_tokens=cfg.enc_tokens)

        cache = {}
        if self.prefix_len:
            cache["prefix"] = [entry(self.specs[i])
                               for i in range(self.prefix_len)]
        if self.repeats:
            sb = {f"t{t}": entry(self.stack_specs[t])
                  for t in range(self.period)}
            cache["stack"] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (self.repeats,) + a.shape),
                sb)
        if self.tail_len:
            base = self.prefix_len + self.repeats * self.period
            cache["tail"] = [entry(self.specs[base + i])
                             for i in range(self.tail_len)]
        return cache

    # ------------------------------------------------------ input specs
    def input_specs(self, shape_cfg):
        """ShapeDtypeStruct stand-ins for every model input of the given
        assigned shape (no allocation). Returns a dict of kwargs for the
        corresponding step function."""
        cfg = self.cfg
        B, S = shape_cfg.global_batch, shape_cfg.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        specs = {}
        if shape_cfg.mode == "train":
            specs["batch"] = {"tokens": sds((B, S + 1), i32)}
            if cfg.frontend == "vision_stub":
                fd = cfg.frontend_dim or cfg.d_model
                specs["batch"]["frontend"] = sds(
                    (B, cfg.frontend_tokens, fd), jnp.float32)
            if cfg.frontend == "audio_stub":
                specs["batch"]["frontend"] = sds(
                    (B, cfg.enc_tokens, cfg.d_model), jnp.float32)
        elif shape_cfg.mode == "prefill":
            specs["tokens"] = sds((B, S), i32)
            if cfg.frontend == "vision_stub":
                fd = cfg.frontend_dim or cfg.d_model
                specs["frontend"] = sds((B, cfg.frontend_tokens, fd),
                                        jnp.float32)
            if cfg.frontend == "audio_stub":
                specs["frontend"] = sds((B, cfg.enc_tokens, cfg.d_model),
                                        jnp.float32)
        else:  # decode
            cap = S + 1 + (cfg.frontend_tokens
                           if cfg.frontend == "vision_stub" else 0)
            specs["token"] = sds((B, 1), i32)
            specs["cache"] = jax.eval_shape(
                lambda: self.make_cache(B, cap))
            specs["pos"] = sds((), i32)
        return specs


def build_model(name_or_cfg, opts: ModelOpts = ModelOpts(),
                reduced: bool = False) -> LanguageModel:
    from repro.configs.base import get_config, ModelConfig as MC
    cfg = (name_or_cfg if isinstance(name_or_cfg, MC)
           else get_config(name_or_cfg))
    if reduced:
        cfg = cfg.reduced()
    return LanguageModel(cfg, opts)
