"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent decay WKV.

Time-mix: token-shift with data-dependent lerp (low-rank), per-head
matrix-valued state S ∈ R^{N×N}:
    y_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ          (w_t data-dependent)
Channel-mix: token-shift + squared-relu 2-matrix FFN.

Sequence path is *chunked*: within a chunk the decay products are formed
as pairwise exp(cum_t − cum_j) with t ≥ j (differences of logs ≤ 0, so no
overflow), the inter-chunk state is carried by lax.scan — the same
blocking the Pallas wkv6 kernel uses on TPU. Validated against the
per-step scan oracle in kernels/wkv6/ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

LORA_RANK = 64


def init_rwkv(cfg, key):
    d, H, N = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 12)
    p = {
        # data-dependent token-shift lerp (5 mixes: r,k,v,w,g)
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),
        "mix_a": dense_init(ks[0], (d, 5 * 32)),
        "mix_b": dense_init(ks[1], (5, 32, d), scale=0.1),
        "wr": dense_init(ks[2], (d, d)),
        "wk": dense_init(ks[3], (d, d)),
        "wv": dense_init(ks[4], (d, d)),
        "wg": dense_init(ks[5], (d, d)),
        "wo": dense_init(ks[6], (d, d)),
        # decay: w = exp(-exp(w0 + lora(x)))
        "w0": -6.0 + jnp.zeros((d,), jnp.float32),
        "wa": dense_init(ks[7], (d, LORA_RANK)),
        "wb": dense_init(ks[8], (LORA_RANK, d), scale=0.1),
        "u": jnp.zeros((H, N), jnp.float32),  # first-token bonus
        "ln_scale": jnp.ones((H, N), jnp.float32),  # per-head groupnorm
        # channel mix
        "cm_mu": 0.5 * jnp.ones((2, d), jnp.float32),
        "cm_k": dense_init(ks[9], (d, cfg.d_ff)),
        "cm_v": dense_init(ks[10], (cfg.d_ff, d)),
        "cm_r": dense_init(ks[11], (d, d)),
    }
    return p


def _token_shift(x, last):
    """x: (B,T,d); last: (B,d) previous token (state). Returns shifted x
    and the new last-token state."""
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev, x[:, -1]


def _ddlerp(p, x, prev):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    dt = x.dtype
    base = x + (prev - x) * p["mu"][0].astype(dt)  # use mu_r as the probe
    lo = jnp.einsum("btd,dr->btr", jnp.tanh(base), p["mix_a"].astype(dt))
    lo = lo.reshape(*lo.shape[:-1], 5, 32)
    delta = jnp.einsum("btfr,frd->btfd", lo, p["mix_b"].astype(dt))
    mix = p["mu"].astype(dt) + delta               # (B,T,5,d)
    xs = x[:, :, None] + (prev - x)[:, :, None] * mix
    return [xs[:, :, i] for i in range(5)]


def _rkvwg(cfg, p, x, prev):
    dt = x.dtype
    xr, xk, xv, xw, xg = _ddlerp(p, x, prev)
    B, T, d = x.shape
    H, N = cfg.n_heads, cfg.head_dim
    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(dt)).reshape(B, T, H, N)
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(dt)).reshape(B, T, H, N)
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(dt)).reshape(B, T, H, N)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"].astype(dt)))
    logw = -jnp.exp(
        p["w0"].astype(jnp.float32)
        + jnp.einsum("btd,dr->btr", jnp.tanh(xw).astype(jnp.float32),
                     p["wa"].astype(jnp.float32))
        @ p["wb"].astype(jnp.float32))             # (B,T,d) <= 0
    logw = logw.reshape(B, T, H, N)
    return r, k, v, g, logw


def wkv_chunked(r, k, v, logw, u, state, chunk=64):
    """Chunked WKV. r,k,v,logw: (B,T,H,N) f32; u: (H,N); state: (B,H,N,N).
    Returns (y (B,T,H,N), final state)."""
    B, T, H, N = r.shape
    pad = (-T) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nc = Tp // chunk
    rc = r.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)
    wc = logw.reshape(B, nc, chunk, H, N).transpose(1, 0, 2, 3, 4)

    def body(S, blk):
        rb, kb, vb, lw = blk                       # (B,L,H,N)
        c = jnp.cumsum(lw, axis=1)                 # inclusive cumsum
        cprev = c - lw                             # c_{t-1}
        # intra-chunk: score[t,j] = sum_i r_t k_j exp(c_{t-1}-c_j), j<t
        dmat = cprev[:, :, None] - c[:, None]      # (B,t,j,H,N)
        tri = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None])
        dmat = jnp.where(tri[None, :, :, None, None], dmat, -jnp.inf)
        score = jnp.einsum("bthn,bjhn,btjhn->btjh", rb, kb,
                           jnp.exp(dmat))
        # diagonal u-bonus term
        sdiag = jnp.einsum("bthn,hn,bthn->bth", rb, u, kb)
        y = jnp.einsum("btjh,bjhn->bthn", score, vb) \
            + sdiag[..., None] * vb
        # inter-chunk: y_t += (r_t * exp(c_{t-1})) @ S
        y = y + jnp.einsum("bthn,bhnm->bthm", rb * jnp.exp(cprev), S)
        # state update: S' = exp(c_L) S + sum_j exp(c_L - c_j) k_j v_j^T
        cl = c[:, -1]                              # (B,H,N)
        S_new = jnp.exp(cl)[..., None] * S + jnp.einsum(
            "bjhn,bjhm->bhnm", kb * jnp.exp(cl[:, None] - c), vb)
        return S_new, y

    state, ys = jax.lax.scan(body, state, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Tp, H, N)[:, :T]
    return y, state


def _headnorm(p, y, eps=1e-5):
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    return (y - mu) * jax.lax.rsqrt(var + eps) * p["ln_scale"]


def rwkv_time_mix_seq(cfg, p, x, state, chunk=64):
    """x: (B,T,d); state: {'S': (B,H,N,N), 'shift': (B,d)}."""
    B, T, d = x.shape
    H, N = cfg.n_heads, cfg.head_dim
    prev, new_shift = _token_shift(x, state["shift"])
    r, k, v, g, logw = _rkvwg(cfg, p, x, prev)
    y, S = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), logw,
                       p["u"].astype(jnp.float32),
                       state["S"].astype(jnp.float32), chunk=chunk)
    y = _headnorm(p, y).reshape(B, T, d).astype(x.dtype) * \
        g.reshape(B, T, d)
    out = jnp.einsum("btd,de->bte", y, p["wo"].astype(x.dtype))
    return out, {"S": S, "shift": new_shift}


def rwkv_channel_mix(cfg, p, x, shift_state):
    dt = x.dtype
    prev, new_shift = _token_shift(x, shift_state)
    xk = x + (prev - x) * p["cm_mu"][0].astype(dt)
    xr = x + (prev - x) * p["cm_mu"][1].astype(dt)
    kk = jnp.square(jax.nn.relu(
        jnp.einsum("btd,df->btf", xk, p["cm_k"].astype(dt))))
    vv = jnp.einsum("btf,fd->btd", kk, p["cm_v"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr,
                                   p["cm_r"].astype(dt)))
    return rr * vv, new_shift
