"""Mamba selective-SSM block (Jamba's recurrent layer, arXiv:2403.19887).

x-dependent (B, C, dt); diagonal A (di, N):
    h_t = exp(dt_t ⊗ A) ⊙ h_{t-1} + (dt_t x_t) ⊗ B_t
    y_t = (h_t · C_t) + D ⊙ x_t
Sequence path: lax.scan over chunks; within a chunk the linear recurrence
runs through lax.associative_scan (exact, parallel — the TPU-native
counterpart of the GPU selective-scan kernel). Decode is the one-step
recurrence with conv + ssm state carried in the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_mamba(cfg, key):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di), scale=0.5),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "bc_proj": dense_init(ks[2], (di, 2 * N)),
        "dt_proj": dense_init(ks[3], (di, 1)),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),
        "A_log": jnp.log(1.0 + jnp.arange(1, N + 1, dtype=jnp.float32)
                         )[None, :] * jnp.ones((di, 1), jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d)),
    }


def _causal_conv(p, x, conv_state):
    """Depthwise causal conv via K shifted adds. x: (B,T,di);
    conv_state: (B,K-1,di) trailing inputs from the previous segment."""
    K = p["conv_w"].shape[0]
    dt = x.dtype
    xx = jnp.concatenate([conv_state.astype(dt), x], axis=1)
    out = sum(xx[:, K - 1 - i: xx.shape[1] - i] * p["conv_w"][K - 1 - i]
              .astype(dt) for i in range(K))
    new_state = xx[:, -(K - 1):]
    return out + p["conv_b"].astype(dt), new_state


def ssm_scan_chunked(u, dt_, B_, C_, A, state, chunk=32):
    """u, dt_: (B,T,di); B_, C_: (B,T,N); A: (di,N) (negative);
    state: (B,di,N). Returns (y (B,T,di), final state)."""
    Bb, T, di = u.shape
    N = A.shape[-1]
    pad = (-T) % chunk
    if pad:
        z3 = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        u, dt_, B_, C_ = z3(u), z3(dt_), z3(B_), z3(C_)
    Tp = u.shape[1]
    nc = Tp // chunk
    r = lambda a: a.reshape(Bb, nc, chunk, a.shape[-1]).transpose(
        1, 0, 2, 3)
    uc, dtc, Bc, Cc = r(u), r(dt_), r(B_), r(C_)

    def body(h0, blk):
        ub, dtb, Bb_, Cb = blk                     # (B,L,·)
        a = jnp.exp(dtb[..., None] * A)            # (B,L,di,N)
        b = (dtb * ub)[..., None] * Bb_[:, :, None]  # (B,L,di,N)

        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, b1 * a2 + b2

        acc_a, acc_b = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = acc_a * h0[:, None] + acc_b            # (B,L,di,N)
        y = jnp.einsum("bldn,bln->bld", h, Cb)
        return h[:, -1], y

    state, ys = jax.lax.scan(body, state, (uc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(Bb, Tp, di)[:, :T]
    return y, state


def mamba_seq(cfg, p, x, state, chunk=32):
    """x: (B,T,d); state: {'conv': (B,K-1,di), 'ssm': (B,di,N)}."""
    dt = x.dtype
    di = cfg.ssm_expand * cfg.d_model
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(dt))
    u, z = xz[..., :di], xz[..., di:]
    u, conv_state = _causal_conv(p, u, state["conv"])
    u = jax.nn.silu(u)
    bc = jnp.einsum("bte,en->btn", u, p["bc_proj"].astype(dt))
    N = cfg.ssm_state
    B_, C_ = bc[..., :N].astype(jnp.float32), bc[..., N:].astype(jnp.float32)
    dt_ = jax.nn.softplus(
        jnp.einsum("bte,eo->bto", u, p["dt_proj"].astype(dt))
        .astype(jnp.float32) + p["dt_bias"])       # (B,T,1) -> broadcast di
    dt_ = jnp.broadcast_to(dt_, u.shape).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssm_scan_chunked(u.astype(jnp.float32), dt_, B_, C_, A,
                                    state["ssm"].astype(jnp.float32),
                                    chunk=chunk)
    y = y + p["D"] * u.astype(jnp.float32)
    y = y.astype(dt) * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(dt))
    return out, {"conv": conv_state, "ssm": ssm_state}


def mamba_decode(cfg, p, x, state):
    """One-step decode; x: (B,1,d)."""
    y, new_state = mamba_seq(cfg, p, x, state, chunk=1)
    return y, new_state
