"""Mixture-of-experts FFN: top-k router + sort-based grouped matmul.

TPU-native dispatch (megablocks adapted to XLA/Pallas): flatten tokens,
sort the (token, expert) assignments by expert, pack into a capacity-
padded (E, C, d) buffer, run a grouped matmul (Pallas `gmm` kernel on
TPU, einsum fallback elsewhere), then unsort and combine with router
weights. Expert axis shards over the `model` mesh axis (expert
parallelism — XLA inserts the all-to-all).

Survey tie-in (§5.4 load balancing): the router emits the standard
load-balance auxiliary loss; benchmarks/fig6 uses the router stats.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(cfg, key):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    d, f = cfg.d_model, m.d_ff
    p = {
        "router": dense_init(ks[0], (d, m.n_experts)),
        "wi": dense_init(ks[1], (m.n_experts, d, f)),
        "wg": dense_init(ks[2], (m.n_experts, d, f)),
        "wo": dense_init(ks[3], (m.n_experts, f, d)),
    }
    if m.n_shared:
        kb = jax.random.split(jax.random.fold_in(key, 7), 3)
        p["shared"] = {
            "wi": dense_init(kb[0], (d, f * m.n_shared)),
            "wg": dense_init(kb[1], (d, f * m.n_shared)),
            "wo": dense_init(kb[2], (f * m.n_shared, d)),
        }
    return p


def _gmm(x, w, use_kernels):
    """Grouped matmul: (E,C,d) @ (E,d,f) -> (E,C,f)."""
    if use_kernels:
        from repro.kernels.gmm import ops as gmm_ops
        return gmm_ops.gmm(x, w)
    return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))


def apply_moe(cfg, p, x, use_kernels=False, local_dispatch=False):
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar).

    local_dispatch=True (§Perf beyond-paper optimization): dispatch is
    vmapped over the batch dim, so the sort/scatter stays *local to each
    data shard* — the only cross-device traffic left is the canonical
    expert-parallel all-to-all on the (E, C, d) buffers. The global path
    sorts over all tokens (better capacity utilisation, but the sort is
    distributed when the batch is sharded — expensive collectives)."""
    m = cfg.moe
    B, S, d = x.shape
    dt = x.dtype
    if local_dispatch:
        outs, auxs = jax.vmap(
            lambda xr: _dispatch_tokens(cfg, p, xr, False))(x)
        out = outs.reshape(B, S, d)
        aux = auxs.mean()
    else:
        out, aux = _dispatch_tokens(cfg, p, x.reshape(B * S, d),
                                    use_kernels)
        out = out.reshape(B, S, d)
    if m.n_shared:
        sp = p["shared"]
        hs = jnp.einsum("bsd,df->bsf", x, sp["wi"].astype(dt))
        gs = jnp.einsum("bsd,df->bsf", x, sp["wg"].astype(dt))
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gs) * hs,
                               sp["wo"].astype(dt))
    return out, aux


def _dispatch_tokens(cfg, p, xt, use_kernels):
    """Routed-expert compute for a flat (T, d) token block."""
    m = cfg.moe
    T, d = xt.shape
    E, K = m.n_experts, m.top_k
    dt = xt.dtype

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(dt))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, K)                       # (T,K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(gates, axis=0)
    aux = jnp.sum(density * density_proxy) * E * m.aux_loss_coef

    # ---- sort-by-expert dispatch with capacity ----
    C = int(max(8, round(T * K / E * m.capacity_factor)))
    fe = topi.reshape(-1)                                      # (T*K,)
    order = jnp.argsort(fe)                                    # stable
    se = fe[order]
    tok_of = order // K
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * K) - first                            # rank in group
    keep = pos < C
    dest = jnp.where(keep, se * C + pos, E * C)                # E*C = drop slot
    buf = jnp.zeros((E * C + 1, d), dt).at[dest].set(xt[tok_of])
    eb = buf[: E * C].reshape(E, C, d)

    h = _gmm(eb, p["wi"], use_kernels)
    g = _gmm(eb, p["wg"], use_kernels)
    o = _gmm(jax.nn.silu(g) * h, p["wo"], use_kernels)         # (E,C,d)

    o_flat = o.reshape(E * C, d)
    gathered = jnp.where(keep[:, None],
                         o_flat[jnp.minimum(dest, E * C - 1)], 0.0)
    w_sorted = topv.reshape(-1)[order][:, None].astype(dt)
    out = jnp.zeros((T, d), dt).at[tok_of].add(gathered * w_sorted)
    return out, aux


def apply_moe_dense_oracle(cfg, p, x):
    """O(T*E) dense-dispatch oracle — math-identical to apply_moe when no
    token is dropped. Used by tests only."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    dt = x.dtype
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(dt))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, m.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    comb = jnp.zeros((xt.shape[0], m.n_experts), jnp.float32)
    comb = jax.vmap(lambda c, i, v: c.at[i].add(v))(comb, topi, topv)
    h = jnp.einsum("td,edf->tef", xt, p["wi"].astype(dt))
    g = jnp.einsum("td,edf->tef", xt, p["wg"].astype(dt))
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * h, p["wo"].astype(dt))
    out = jnp.einsum("ted,te->td", o.astype(jnp.float32), comb).astype(dt)
    if m.n_shared:
        sp = p["shared"]
        hs = jnp.einsum("td,df->tf", xt, sp["wi"].astype(dt))
        gs = jnp.einsum("td,df->tf", xt, sp["wg"].astype(dt))
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(gs) * hs,
                               sp["wo"].astype(dt))
    return out.reshape(B, S, d)
