"""Per-layer block: token mixer (attn/local/MLA/RWKV/Mamba) + channel
mixer (dense SwiGLU or MoE), pre-norm residual. Whisper decoder blocks add
cross-attention. One entry point per execution mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, MLA, RWKV, MAMBA
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (init_norm, apply_norm, init_mlp, apply_mlp,
                                 init_mlp_gelu, apply_mlp_gelu)


def init_block(cfg, key, kind: str, is_moe: bool, has_cross: bool = False,
               gelu_mlp: bool = False):
    ks = jax.random.split(key, 6)
    p = {"norm1": init_norm(cfg)}
    if kind in (ATTN, ATTN_LOCAL):
        p["mixer"] = attn.init_attn(cfg, ks[0], kind)
    elif kind == MLA:
        p["mixer"] = attn.init_attn(cfg, ks[0], MLA)
    elif kind == RWKV:
        p["mixer"] = rwkv_mod.init_rwkv(cfg, ks[0])
        p["norm2"] = init_norm(cfg)
        return p  # rwkv channel-mix params live inside the mixer
    elif kind == MAMBA:
        p["mixer"] = mamba_mod.init_mamba(cfg, ks[0])
    else:
        raise ValueError(kind)
    if has_cross:
        p["xnorm"] = init_norm(cfg)
        p["xattn"] = attn.init_cross_attn(cfg, ks[1])
    p["norm2"] = init_norm(cfg)
    if is_moe:
        p["ffn"] = moe_mod.init_moe(cfg, ks[2])
    elif gelu_mlp:
        p["ffn"] = init_mlp_gelu(cfg, ks[2])
    else:
        p["ffn"] = init_mlp(cfg, ks[2])
    return p


def init_cache(cfg, kind: str, batch: int, capacity: int, dtype,
               has_cross: bool = False, enc_tokens: int = 0):
    """Zero/empty cache entry for one layer."""
    H, KVH, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if kind in (ATTN, ATTN_LOCAL):
        C = min(capacity, cfg.window) if kind == ATTN_LOCAL else capacity
        c = {"k": jnp.zeros((batch, C, KVH, D), dtype),
             "v": jnp.zeros((batch, C, KVH, D), dtype)}
    elif kind == MLA:
        c = {"ckv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
             "kr": jnp.zeros((batch, capacity, cfg.rope_head_dim), dtype)}
    elif kind == RWKV:
        c = {"S": jnp.zeros((batch, H, D, D), jnp.float32),
             "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
             "shift_cm": jnp.zeros((batch, cfg.d_model), dtype)}
    elif kind == MAMBA:
        di = cfg.ssm_expand * cfg.d_model
        c = {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
             "ssm": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)}
    else:
        raise ValueError(kind)
    if has_cross:
        c["ek"] = jnp.zeros((batch, enc_tokens, KVH, D), dtype)
        c["ev"] = jnp.zeros((batch, enc_tokens, KVH, D), dtype)
    return c


def _cross_kv(cfg, p, enc_out):
    dt = enc_out.dtype
    ek = jnp.einsum("btd,dhk->bthk", enc_out, p["xattn"]["wk"].astype(dt))
    ev = jnp.einsum("btd,dhk->bthk", enc_out, p["xattn"]["wv"].astype(dt))
    return ek, ev


def apply_block_seq(cfg, p, kind, is_moe, x, pos0, opts, *,
                    cache_capacity=0, enc_out=None, cache_in=None,
                    gelu_mlp=False, causal=True):
    """Train (cache_capacity=0) / prefill (>0) path. Returns
    (x, cache, aux_loss). `cache_in` supplies initial recurrent states
    (zeros when None)."""
    B = x.shape[0]
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    h = apply_norm(p["norm1"], x)
    if kind == RWKV:
        st = cache_in or init_cache(cfg, RWKV, B, 0, x.dtype)
        o, tm = rwkv_mod.rwkv_time_mix_seq(
            cfg, p["mixer"], h, {"S": st["S"], "shift": st["shift_tm"]})
        x = x + o
        h2 = apply_norm(p["norm2"], x)
        o2, shift_cm = rwkv_mod.rwkv_channel_mix(cfg, p["mixer"], h2,
                                                 st["shift_cm"])
        x = x + o2
        if cache_capacity:
            cache = {"S": tm["S"], "shift_tm": tm["shift"],
                     "shift_cm": shift_cm}
        return x, cache, aux
    if kind == MAMBA:
        st = cache_in or init_cache(cfg, MAMBA, B, 0, x.dtype)
        o, new_st = mamba_mod.mamba_seq(cfg, p["mixer"], h, st)
        if cache_capacity:
            cache.update(new_st)
    elif kind == MLA:
        o, c = attn.mla_seq(cfg, p["mixer"], h, pos0, opts,
                            cache_capacity=cache_capacity)
        if c:
            cache.update(c)
    else:
        o, c = attn.gqa_seq(cfg, p["mixer"], h, pos0, kind, opts,
                            cache_capacity=cache_capacity, causal=causal)
        if c:
            cache.update(c)
    x = x + o
    if enc_out is not None and "xattn" in p:
        hx = apply_norm(p["xnorm"], x)
        ek, ev = _cross_kv(cfg, p, enc_out)
        ox, _ = attn.gqa_seq(cfg, p["xattn"], hx, pos0, ATTN, opts,
                             cross_kv=(ek, ev))
        x = x + ox
        if cache_capacity:
            cache["ek"], cache["ev"] = ek, ev
    h2 = apply_norm(p["norm2"], x)
    if is_moe:
        o2, aux = moe_mod.apply_moe(cfg, p["ffn"], h2,
                                    use_kernels=opts.use_kernels,
                                    local_dispatch=opts.moe_local)
    elif gelu_mlp:
        o2 = apply_mlp_gelu(p["ffn"], h2)
    else:
        o2 = apply_mlp(p["ffn"], h2)
    return x + o2, cache, aux


def apply_block_decode(cfg, p, kind, is_moe, x, cache, pos, opts,
                       gelu_mlp=False):
    """One-token decode. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x)
    new_cache = dict(cache)
    if kind == RWKV:
        o, tm = rwkv_mod.rwkv_time_mix_seq(
            cfg, p["mixer"], h, {"S": cache["S"],
                                 "shift": cache["shift_tm"]}, chunk=1)
        x = x + o
        h2 = apply_norm(p["norm2"], x)
        o2, shift_cm = rwkv_mod.rwkv_channel_mix(cfg, p["mixer"], h2,
                                                 cache["shift_cm"])
        new_cache = {"S": tm["S"], "shift_tm": tm["shift"],
                     "shift_cm": shift_cm}
        return x + o2, new_cache, aux
    if kind == MAMBA:
        o, st = mamba_mod.mamba_decode(
            cfg, p["mixer"], h, {"conv": cache["conv"],
                                 "ssm": cache["ssm"]})
        new_cache.update(st)
    elif kind == MLA:
        o, c = attn.mla_decode(cfg, p["mixer"], h,
                               {"ckv": cache["ckv"], "kr": cache["kr"]},
                               pos, opts)
        new_cache.update(c)
    else:
        o, c = attn.gqa_decode(cfg, p["mixer"], h,
                               {"k": cache["k"], "v": cache["v"]},
                               pos, kind, opts)
        new_cache.update(c)
    x = x + o
    if "xattn" in p and "ek" in cache:
        hx = apply_norm(p["xnorm"], x)
        ox, _ = attn.gqa_decode(cfg, p["xattn"], hx, None, pos, ATTN, opts,
                                cross_kv=(cache["ek"], cache["ev"]))
        x = x + ox
    h2 = apply_norm(p["norm2"], x)
    if is_moe:
        o2, aux = moe_mod.apply_moe(cfg, p["ffn"], h2,
                                    use_kernels=opts.use_kernels,
                                    local_dispatch=opts.moe_local)
    elif gelu_mlp:
        o2 = apply_mlp_gelu(p["ffn"], h2)
    else:
        o2 = apply_mlp(p["ffn"], h2)
    return x + o2, new_cache, aux
