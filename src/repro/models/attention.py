"""Attention token mixers: GQA (full + sliding-window) and MLA.

Three execution paths per mixer:
  * seq (train / prefill): full-sequence causal attention, computed with a
    memory-bounded blockwise online-softmax ("flash" in pure jnp — the
    Pallas kernel in repro.kernels.flash_attention is the TPU version and
    is validated against the same oracle). The causal quadratic is chunked
    over the query axis in a *static python loop* so each chunk only ever
    lowers matmuls against its own prefix — keeping HLO FLOPs within ~6%
    of the true causal cost (important for the roofline terms).
  * local (sliding window): exact banded block attention — O(S*w) compute.
  * decode: one query token against a KV cache (ring buffer for local
    layers so a 500k context only needs a `window`-sized cache).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, MLA
from repro.models.layers import dense_init, apply_rope, init_norm, apply_norm

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnOpts:
    dtype: jnp.dtype = jnp.bfloat16
    block_k: int = 512       # kv block for online softmax
    n_q_chunks: int = 8      # static causal query chunks
    use_kernels: bool = False  # route seq attention through Pallas
    moe_local: bool = False    # row-local MoE dispatch (see models/moe.py)


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def init_attn(cfg, key, kind: str):
    ks = jax.random.split(key, 8)
    hd = cfg.head_dim
    if kind == MLA:
        rq = cfg.q_lora_rank or cfg.d_model
        rkv = cfg.kv_lora_rank
        hr = cfg.rope_head_dim
        p = {
            "wdq": dense_init(ks[0], (cfg.d_model, rq)),
            "q_norm": {"scale": jnp.ones((rq,), jnp.float32)},
            "wuq": dense_init(ks[1], (rq, cfg.n_heads, hd)),
            "wqr": dense_init(ks[2], (rq, cfg.n_heads, hr)),
            "wdkv": dense_init(ks[3], (cfg.d_model, rkv)),
            "kv_norm": {"scale": jnp.ones((rkv,), jnp.float32)},
            "wkr": dense_init(ks[4], (cfg.d_model, hr)),
            "wuk": dense_init(ks[5], (rkv, cfg.n_heads, hd)),
            "wuv": dense_init(ks[6], (rkv, cfg.n_heads, hd)),
            "wo": dense_init(ks[7], (cfg.n_heads, hd, cfg.d_model)),
        }
        return p
    return {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads, hd)),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd)),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd)),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, cfg.d_model)),
    }


def init_cross_attn(cfg, key):
    """Whisper decoder cross-attention (same shapes as MHA)."""
    return init_attn(cfg, key, ATTN)


# ---------------------------------------------------------------------------
# Blockwise online-softmax attention core (pure jnp "flash")
# ---------------------------------------------------------------------------

def emit_ring(k, C):
    """Lay out per-position entries k (B,S,...) into a ring cache of
    capacity C such that position p sits in slot p % C. Requires C >= S
    (pad right) or S % C == 0 (keep last C — slots align)."""
    S = k.shape[1]
    if C >= S:
        widths = [(0, 0)] * k.ndim
        widths[1] = (0, C - S)
        return jnp.pad(k, widths)
    assert S % C == 0, f"ring cache needs S%C==0, got S={S} C={C}"
    return k[:, -C:]


def _pad_axis(x, axis, to_multiple):
    n = x.shape[axis]
    pad = (-n) % to_multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def flash_block_attention(q, k, v, q_pos, kv_pos0, *, causal: bool,
                          window: int, block_k: int, kv_valid_len=None):
    """q: (B,Sq,KVH,G,D) k/v: (B,T,KVH,Dk|Dv); returns (B,Sq,KVH,G,Dv).

    kv positions are kv_pos0 + arange(T); entries at index >= kv_valid_len
    (a traced scalar or None) are masked out. Online softmax over kv
    blocks via lax.scan keeps live memory at one (…, Sq, block_k) tile.
    """
    B, Sq, KVH, G, D = q.shape
    Dv = v.shape[-1]
    scale = D ** -0.5
    k, T0 = _pad_axis(k, 1, block_k)
    v, _ = _pad_axis(v, 1, block_k)
    T = k.shape[1]
    nk = T // block_k
    kpos = kv_pos0 + jnp.arange(T)
    if kv_valid_len is None:
        kv_valid = jnp.arange(T) < T0
    else:
        kv_valid = jnp.arange(T) < kv_valid_len

    kb = k.reshape(B, nk, block_k, KVH, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, block_k, KVH, Dv).transpose(1, 0, 2, 3, 4)
    kposb = kpos.reshape(nk, block_k)
    kvalb = kv_valid.reshape(nk, block_k)

    qf = q.astype(jnp.float32) * scale

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, kp, kval = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kblk.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        mask = kval[None, :]
        if causal:
            mask = mask & (kp[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (kp[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, KVH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, kposb, kvalb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,KVH,G,Dv)


def causal_attention(q, k, v, pos0, *, n_q_chunks: int, block_k: int):
    """Exact-ish causal full attention, q:(B,S,KVH,G,D) k,v:(B,S,KVH,D).

    Static python loop over query chunks; chunk i only multiplies against
    its own static kv prefix — HLO flops ≈ true causal flops (overcount
    bounded by 1/(2*n_q_chunks))."""
    B, S, KVH, G, D = q.shape
    nq = max(1, min(n_q_chunks, S // max(1, min(block_k, S))))
    cs = -(-S // nq)  # ceil
    outs = []
    for i in range(nq):
        lo, hi = i * cs, min((i + 1) * cs, S)
        if lo >= S:
            break
        qc = q[:, lo:hi]
        qpos = pos0 + jnp.arange(lo, hi)
        kv_hi = hi  # causal prefix
        o = flash_block_attention(
            qc, k[:, :kv_hi], v[:, :kv_hi], qpos, pos0,
            causal=True, window=0, block_k=min(block_k, kv_hi))
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def local_attention(q, k, v, pos0, *, window: int):
    """Exact banded sliding-window attention, O(S*window).

    Reshape the sequence into blocks of `window`; each query block attends
    to [previous block ‖ own block] with the in-window mask."""
    B, S, KVH, G, D = q.shape
    w = window
    q, S0 = _pad_axis(q, 1, w)
    k, _ = _pad_axis(k, 1, w)
    v, _ = _pad_axis(v, 1, w)
    S = q.shape[1]
    nb = S // w
    qb = q.reshape(B, nb, w, KVH, G, D)
    kb = k.reshape(B, nb, w, KVH, D)
    vb = v.reshape(B, nb, w, KVH, D)
    # previous block (block -1 is zeros, fully masked out by position)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)  # (B,nb,2w,KVH,D)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    scale = D ** -0.5
    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qb.astype(jnp.float32) * scale,
                   k2.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    qpos = jnp.arange(S).reshape(nb, w)                       # (nb,w)
    kpos = (jnp.arange(2 * w)[None] - w) + (jnp.arange(nb) * w)[:, None]
    valid = (kpos[:, None, :] <= qpos[..., None]) \
        & (kpos[:, None, :] > qpos[..., None] - w) \
        & (kpos[:, None, :] >= 0) & (kpos[:, None, :] < S0) \
        & (qpos[..., None] < S0)
    s = jnp.where(valid[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p, v2.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, S, KVH, G, D)[:, :S0]
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA mixer
# ---------------------------------------------------------------------------

def _qkv(cfg, p, x):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    return q, k, v


def gqa_seq(cfg, p, x, pos0, kind, opts: AttnOpts, cache_capacity=0,
            cross_kv=None, causal=True):
    """Full-sequence GQA. Returns (out, cache) — cache sized
    `cache_capacity` (0 = no cache emitted, train mode)."""
    B, S, _ = x.shape
    H, KVH, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KVH
    q, k, v = _qkv(cfg, p, x)
    if cross_kv is not None:
        ek, ev = cross_kv  # (B,Te,KVH,D) — whisper cross attention
        qg = q.reshape(B, S, KVH, G, D)
        o = flash_block_attention(qg, ek, ev, jnp.zeros((S,), jnp.int32),
                                  jnp.array(0), causal=False, window=0,
                                  block_k=min(opts.block_k, ek.shape[1]))
        o = o.reshape(B, S, H, D)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
        return out, None
    positions = pos0 + jnp.arange(S)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    qg = q.reshape(B, S, KVH, G, D)
    if not causal:  # encoder self-attention — single non-causal pass
        o = flash_block_attention(qg, k, v, positions, pos0, causal=False,
                                  window=0, block_k=min(opts.block_k, S))
    elif kind == ATTN_LOCAL:
        o = local_attention(qg, k, v, pos0, window=cfg.window)
    elif opts.use_kernels:
        # core dispatcher: Pallas flash attention on TPU, the shared
        # ref oracle elsewhere (interpret-mode Pallas is orders of
        # magnitude slower than the oracle on CPU)
        from repro.core.attention import attention as core_attention
        o = core_attention(qg, k, v, causal=True, use_kernel=True)
    else:
        o = causal_attention(qg, k, v, pos0, n_q_chunks=opts.n_q_chunks,
                             block_k=opts.block_k)
    o = o.reshape(B, S, H, D)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    cache = None
    if cache_capacity:
        C = cache_capacity
        if kind == ATTN_LOCAL:
            C = min(C, cfg.window)
        cache = {"k": emit_ring(k, C), "v": emit_ring(v, C)}
    return out, cache


def gqa_decode(cfg, p, x, cache, pos, kind, opts: AttnOpts,
               cross_kv=None):
    """One-token decode. x: (B,1,d); cache {'k','v'}: (B,C,KVH,D); pos:
    scalar int32 — position of this token. Ring-buffer write at pos % C.
    Assumes the cache is full (pos >= C), true for the assigned decode
    shapes (cache length == context length)."""
    B = x.shape[0]
    H, KVH, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KVH
    dt = x.dtype
    q, k, v = _qkv(cfg, p, x)
    if cross_kv is not None:
        ek, ev = cross_kv
        s = jnp.einsum("bohk,bthk->bhot", q.reshape(B, 1, H, D) * D**-0.5,
                       jnp.repeat(ek, G, axis=2).astype(dt))
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(dt)
        o = jnp.einsum("bhot,bthk->bohk", w, jnp.repeat(ev, G, axis=2))
        out = jnp.einsum("bohk,hkd->bod", o, p["wo"].astype(dt))
        return out, cache
    q = apply_rope(q, pos[None] if pos.ndim == 0 else pos, cfg.rope_theta)
    k = apply_rope(k, pos[None] if pos.ndim == 0 else pos, cfg.rope_theta)
    C = cache["k"].shape[1]
    slot = (pos % C).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    qg = q.reshape(B, 1, KVH, G, D).astype(jnp.float32) * D**-0.5
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg, ck.astype(jnp.float32),
                   preferred_element_type=jnp.float32)  # (B,KVH,G,1,C)
    if kind == ATTN_LOCAL:
        pass  # ring holds exactly the window — everything valid
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthd->bqhgd", w, cv.astype(jnp.float32))
    o = o.reshape(B, 1, H, D).astype(dt)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA mixer (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------

def _mla_q(cfg, p, x):
    dt = x.dtype
    cq = jnp.einsum("bsd,dr->bsr", x, p["wdq"].astype(dt))
    cq = apply_norm(p["q_norm"], cq)
    q_nope = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"].astype(dt))
    q_rope = jnp.einsum("bsr,rhk->bshk", cq, p["wqr"].astype(dt))
    return q_nope, q_rope


def _mla_latents(cfg, p, x, positions):
    dt = x.dtype
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(dt))
    ckv = apply_norm(p["kv_norm"], ckv)
    kr = jnp.einsum("bsd,dk->bsk", x, p["wkr"].astype(dt))
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, kr


def mla_seq(cfg, p, x, pos0, opts: AttnOpts, cache_capacity=0):
    """Full-sequence MLA: expand latents to per-head K/V and reuse the
    causal flash path (q/k concat [nope‖rope])."""
    B, S, _ = x.shape
    H, D, HR = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    dt = x.dtype
    positions = pos0 + jnp.arange(S)
    q_nope, q_rope = _mla_q(cfg, p, x)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv, kr = _mla_latents(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"].astype(dt))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)            # (B,S,H,D+HR)
    k = jnp.concatenate([k_nope, jnp.repeat(kr[:, :, None], H, 2)], axis=-1)
    qg = q.reshape(B, S, H, 1, D + HR)
    o = causal_attention(qg, k, v, pos0, n_q_chunks=opts.n_q_chunks,
                         block_k=opts.block_k)
    o = o.reshape(B, S, H, D)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    cache = None
    if cache_capacity:
        C = cache_capacity
        cache = {"ckv": emit_ring(ckv, C), "kr": emit_ring(kr, C)}
    return out, cache


def mla_decode(cfg, p, x, cache, pos, opts: AttnOpts):
    """Absorbed-matmul MLA decode: score against the compressed latent
    cache directly — the cache per token is only (r_kv + rope_dim)."""
    B = x.shape[0]
    H, D, HR = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    dt = x.dtype
    q_nope, q_rope = _mla_q(cfg, p, x)
    q_rope = apply_rope(q_rope, pos[None] if pos.ndim == 0 else pos,
                        cfg.rope_theta)
    ckv_t, kr_t = _mla_latents(cfg, p, x, pos[None] if pos.ndim == 0
                               else pos)
    C = cache["ckv"].shape[1]
    slot = (pos % C).astype(jnp.int32)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_t, slot, 1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_t, slot, 1)
    # absorb W_uk into q:  (B,1,H,D) x (r,H,D) -> (B,1,H,r)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"].astype(dt))
    scale = (D + HR) ** -0.5
    s = (jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32),
                    ckv.astype(jnp.float32)) +
         jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                    kr.astype(jnp.float32))) * scale
    w = jax.nn.softmax(s, axis=-1)                            # (B,H,1,C)
    o_lat = jnp.einsum("bhst,btr->bshr", w, ckv.astype(jnp.float32))
    o = jnp.einsum("bshr,rhk->bshk", o_lat.astype(dt), p["wuv"].astype(dt))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, {"ckv": ckv, "kr": kr}
