"""Optimizers built from scratch in JAX (no optax): AdamW, SGD(+momentum),
Lion, global-norm clipping, cosine LR schedule. optax-like
(init/update) interface; all states are pytrees of arrays so they shard
with the params (relevant for the ZeRO-style FSDP option)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)
    # Optional split of `update` for ZeRO-style sharded application
    # (repro.core.topology.zero_sharded_optimizer): `pre` is the piece
    # that must see the FULL gradient pytree (e.g. global-norm clipping
    # — its norm over a 1/n shard would differ), `shard_update` the
    # purely per-coordinate remainder, with the invariant
    # ``update(g, s, p) == shard_update(pre(g), s, p)``. Both stay None
    # for optimizers whose update is already per-coordinate (adamw /
    # sgd / lion) — the shard wrapper then slices `update` directly.
    pre: Optional[Callable] = None
    shard_update: Optional[Callable] = None

    def apply(self, params, state, grads):
        updates, state = self.update(grads, state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params,
                                        updates)
        return params, state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak, total_steps, warmup=0, floor=0.0):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                     0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return sched


def sgd(lr, momentum: float = 0.0):
    def init(params):
        mu = (jax.tree_util.tree_map(jnp.zeros_like, params)
              if momentum else None)
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mu"], grads)
            upd = jax.tree_util.tree_map(lambda m: -lr_t * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree_util.tree_map(lambda g: -lr_t * g, grads)
        return upd, {"step": step, "mu": None}

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
          moment_dtype=jnp.float32):
    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        m = jax.tree_util.tree_map(
            lambda m_, g: (b1 * m_ + (1 - b1) * g.astype(moment_dtype)),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: (b2 * v_
                           + (1 - b2) * jnp.square(g.astype(moment_dtype))),
            state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            mhat = m_.astype(jnp.float32) / bc1
            vhat = v_.astype(jnp.float32) / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def lion(lr, b1=0.9, b2=0.99, weight_decay=0.0):
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)

        def upd(m_, g, p):
            u = jnp.sign(b1 * m_ + (1 - b1) * g)
            if weight_decay:
                u = u + weight_decay * p
            return -lr_t * u

        updates = jax.tree_util.tree_map(upd, state["m"], grads, params)
        m = jax.tree_util.tree_map(
            lambda m_, g: b2 * m_ + (1 - b2) * g, state["m"], grads)
        return updates, {"step": step, "m": m}

    return Optimizer(init, update)


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    def clip(grads):
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
        return jax.tree_util.tree_map(lambda g: g * scale, grads)

    # compose as pre/shard_update so a ZeRO shard wrapper can run the
    # clip on the full gradients and only the inner per-coordinate
    # update on the local slice; `update` is bitwise what it always was
    inner_pre = opt.pre
    pre = clip if inner_pre is None else (lambda g: inner_pre(clip(g)))
    bare = opt.shard_update if opt.pre is not None else opt.update

    def update(grads, state, params):
        return bare(pre(grads), state, params)

    return Optimizer(opt.init, update, pre=pre, shard_update=bare)


def chain(opt: Optimizer, *wrappers) -> Optimizer:
    for w in wrappers:
        opt = w(opt)
    return opt
