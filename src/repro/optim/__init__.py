from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, sgd, lion, clip_by_global_norm, chain,
    cosine_schedule, global_norm)
