"""Experience replay (survey §3: Gorila/Ape-X Replay Memory component).

Pure-functional fixed-capacity buffers living on device:
  * `UniformReplay` — Gorila-style uniform sampling.
  * `PrioritizedReplay` — Ape-X style proportional prioritization
    p_i ∝ |TD_i|^α with importance-sampling weights w_i ∝ (N p_i)^{-β};
    sampling via categorical over log-priorities (TPU-friendly — no
    host-side sum-tree).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class UniformReplay:
    capacity: int

    def init(self, example: Any):
        store = jax.tree_util.tree_map(
            lambda a: jnp.zeros((self.capacity,) + jnp.shape(a),
                                jnp.asarray(a).dtype), example)
        return {"store": store, "ptr": jnp.zeros((), jnp.int32),
                "size": jnp.zeros((), jnp.int32)}

    def add_batch(self, state, batch):
        """batch: pytree with leading dim n (n <= capacity)."""
        n = jax.tree_util.tree_leaves(batch)[0].shape[0]
        idx = (state["ptr"] + jnp.arange(n)) % self.capacity
        store = jax.tree_util.tree_map(
            lambda s, b: s.at[idx].set(b), state["store"], batch)
        return {"store": store, "ptr": (state["ptr"] + n) % self.capacity,
                "size": jnp.minimum(state["size"] + n, self.capacity)}

    def sample(self, state, key, n):
        idx = jax.random.randint(key, (n,), 0, jnp.maximum(state["size"],
                                                           1))
        return jax.tree_util.tree_map(lambda s: s[idx], state["store"]), idx


@dataclasses.dataclass
class PrioritizedReplay:
    capacity: int
    alpha: float = 0.6
    beta: float = 0.4
    eps: float = 1e-6

    def init(self, example: Any):
        store = jax.tree_util.tree_map(
            lambda a: jnp.zeros((self.capacity,) + jnp.shape(a),
                                jnp.asarray(a).dtype), example)
        return {"store": store, "prio": jnp.zeros((self.capacity,)),
                "ptr": jnp.zeros((), jnp.int32),
                "size": jnp.zeros((), jnp.int32)}

    def add_batch(self, state, batch, priorities=None):
        n = jax.tree_util.tree_leaves(batch)[0].shape[0]
        idx = (state["ptr"] + jnp.arange(n)) % self.capacity
        store = jax.tree_util.tree_map(
            lambda s, b: s.at[idx].set(b), state["store"], batch)
        if priorities is None:  # new samples get max priority (Ape-X)
            priorities = jnp.full((n,), jnp.maximum(
                state["prio"].max(), 1.0))
        prio = state["prio"].at[idx].set(priorities)
        return {"store": store, "prio": prio,
                "ptr": (state["ptr"] + n) % self.capacity,
                "size": jnp.minimum(state["size"] + n, self.capacity)}

    def sample(self, state, key, n):
        """-> (batch, idx, is_weights). Proportional sampling WITH
        replacement: idx ~ p_i^α via categorical over log-priorities
        (TPU-friendly; no host-side sum-tree)."""
        valid = jnp.arange(self.capacity) < state["size"]
        logits = self.alpha * jnp.log(state["prio"] + self.eps)
        logits = jnp.where(valid, logits, -jnp.inf)
        idx = jax.random.categorical(key, logits, shape=(n,))
        probs = jax.nn.softmax(logits)
        N = jnp.maximum(state["size"], 1)
        w = (N * probs[idx] + 1e-12) ** (-self.beta)
        w = w / jnp.maximum(w.max(), 1e-12)
        batch = jax.tree_util.tree_map(lambda s: s[idx], state["store"])
        return batch, idx, w

    def update_priorities(self, state, idx, td_errors):
        prio = state["prio"].at[idx].set(jnp.abs(td_errors) + self.eps)
        return dict(state, prio=prio)
