"""Experience replay (survey §3: Gorila/Ape-X Replay Memory component).

Pure-functional fixed-capacity buffers living on device:
  * `UniformReplay` — Gorila-style uniform sampling.
  * `PrioritizedReplay` — Ape-X style proportional prioritization
    p_i ∝ |TD_i|^α with importance-sampling weights w_i ∝ (N p_i)^{-β}.
    Two sampling paths (TPU-friendly either way — no host-side
    sum-tree):
      - legacy (`fused=False`, default): n independent categorical
        draws over log-priorities (WITH replacement); the IS weights
        gather the chosen logits and normalize by the scalar partition
        function — bitwise what the old full-capacity
        `jax.nn.softmax` materialization computed, without it.
      - fused (`fused=True`): one Gumbel-top-k pass (WITHOUT
        replacement) through `core.replay_sample` — the Pallas kernel
        on TPU, its jnp oracle elsewhere.

Edge cases (both buffers):
  * Sampling from an EMPTY buffer (size == 0) is well-defined but
    degenerate: every draw returns slot 0 — the zeros `init` wrote —
    with finite weights. Callers must gate on warmup/size (see
    algos/dqn.py); there is no in-graph error because `size` is traced.
  * `add_batch` with n > capacity used to self-overwrite through
    duplicate ring indices (unspecified scatter order); since n is
    static it is now guarded explicitly — only the LAST `capacity`
    items are written (ring semantics), deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.replay_sample import fused_prioritized_sample


def _ring_fit(state, batch, capacity, priorities=None):
    """Ring-write plan for n items: with n > capacity, drop all but the
    last `capacity` (they would be overwritten within this very batch —
    the old duplicate-index scatter relied on unspecified ordering to
    do the same). Returns (idx, batch, priorities, new_ptr)."""
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    drop = max(n - capacity, 0)
    if drop:
        batch = jax.tree_util.tree_map(lambda b: b[drop:], batch)
        if priorities is not None:
            priorities = priorities[drop:]
    idx = (state["ptr"] + drop + jnp.arange(n - drop)) % capacity
    return idx, batch, priorities, (state["ptr"] + n) % capacity


@dataclasses.dataclass
class UniformReplay:
    capacity: int

    def init(self, example: Any):
        store = jax.tree_util.tree_map(
            lambda a: jnp.zeros((self.capacity,) + jnp.shape(a),
                                jnp.asarray(a).dtype), example)
        return {"store": store, "ptr": jnp.zeros((), jnp.int32),
                "size": jnp.zeros((), jnp.int32)}

    def add_batch(self, state, batch):
        """batch: pytree with leading dim n (n > capacity keeps only the
        last `capacity` items — see module docstring)."""
        n = jax.tree_util.tree_leaves(batch)[0].shape[0]
        idx, batch, _, ptr = _ring_fit(state, batch, self.capacity)
        store = jax.tree_util.tree_map(
            lambda s, b: s.at[idx].set(b), state["store"], batch)
        return {"store": store, "ptr": ptr,
                "size": jnp.minimum(state["size"] + n, self.capacity)}

    def sample(self, state, key, n):
        """Uniform over filled slots. Empty buffer -> slot-0 zeros (see
        module docstring)."""
        idx = jax.random.randint(key, (n,), 0, jnp.maximum(state["size"],
                                                           1))
        return jax.tree_util.tree_map(lambda s: s[idx], state["store"]), idx


@dataclasses.dataclass
class PrioritizedReplay:
    capacity: int
    alpha: float = 0.6
    beta: float = 0.4
    eps: float = 1e-6
    fused: bool = False   # Gumbel-top-k kernel path (see module doc)

    def init(self, example: Any):
        store = jax.tree_util.tree_map(
            lambda a: jnp.zeros((self.capacity,) + jnp.shape(a),
                                jnp.asarray(a).dtype), example)
        return {"store": store, "prio": jnp.zeros((self.capacity,)),
                "ptr": jnp.zeros((), jnp.int32),
                "size": jnp.zeros((), jnp.int32)}

    def add_batch(self, state, batch, priorities=None):
        n = jax.tree_util.tree_leaves(batch)[0].shape[0]
        idx, batch, priorities, ptr = _ring_fit(state, batch,
                                                self.capacity, priorities)
        store = jax.tree_util.tree_map(
            lambda s, b: s.at[idx].set(b), state["store"], batch)
        if priorities is None:  # new samples get max priority (Ape-X)
            priorities = jnp.full((idx.shape[0],), jnp.maximum(
                state["prio"].max(), 1.0))
        prio = state["prio"].at[idx].set(priorities)
        return {"store": store, "prio": prio, "ptr": ptr,
                "size": jnp.minimum(state["size"] + n, self.capacity)}

    def sample(self, state, key, n):
        """-> (batch, idx, is_weights). Proportional to p_i^α; WITH
        replacement on the legacy path, WITHOUT (Gumbel-top-k) on the
        fused path. Empty buffer -> finite-weight slot-0 draws."""
        if self.fused:
            gumbel = jax.random.gumbel(key, (self.capacity,))
            idx, w = fused_prioritized_sample(
                state["prio"], state["size"], gumbel, n,
                self.alpha, self.beta, self.eps, use_kernel=True)
        else:
            # the arange guard keeps slot 0 "valid" when empty so the
            # normalization below stays NaN-free (bitwise unchanged
            # whenever size >= 1)
            valid = jnp.arange(self.capacity) < jnp.maximum(state["size"],
                                                            1)
            logits = self.alpha * jnp.log(state["prio"] + self.eps)
            logits = jnp.where(valid, logits, -jnp.inf)
            idx = jax.random.categorical(key, logits, shape=(n,))
            # π_idx gathered from the chosen logits + scalar partition
            # function — no capacity-sized softmax materialization
            unnorm = jnp.exp(logits - jnp.max(logits))
            N = jnp.maximum(state["size"], 1)
            w = (N * (unnorm[idx] / unnorm.sum()) + 1e-12) ** (-self.beta)
            w = w / jnp.maximum(w.max(), 1e-12)
        batch = jax.tree_util.tree_map(lambda s: s[idx], state["store"])
        return batch, idx, w

    def update_priorities(self, state, idx, td_errors):
        prio = state["prio"].at[idx].set(jnp.abs(td_errors) + self.eps)
        return dict(state, prio=prio)
