"""Attention on the learner hot path — public API.

House ref/kernel/ops convention (same seam as core/vtrace.py): the
model-side grouped-query layout (B, S, KVH, G, D) dispatches to the
Pallas flash-attention kernel (kernels/flash_attention/ops.py) on TPU
and to the pure-jnp oracle (kernels/flash_attention/ref.py) elsewhere,
so the transformer policy trunk (networks.TrunkPolicy) trains through
one call site on every backend. Both paths share the oracle; parity is
pinned in tests/test_kernels.py.
"""
import jax.numpy as jnp

from repro.kernels.common import interpret_mode
from repro.kernels.flash_attention.ref import attention_ref


def attention(qg, k, v, *, causal=True, window=0, use_kernel=False):
    """Grouped-query attention over the model layout.

    qg: (B, S, KVH, G, D) queries grouped per kv head; k, v:
    (B, S, KVH, D). Returns (B, S, KVH, G, D). `window` > 0 keeps only
    the trailing `window` keys per query (sliding-window attention)."""
    if use_kernel and not interpret_mode():
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(qg, k, v, causal=causal, window=window)
    B, S, KVH, G, D = qg.shape
    q = jnp.moveaxis(qg.reshape(B, S, KVH * G, D), 1, 2)  # (B, H, S, D)
    o = attention_ref(q, jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
                      causal=causal, window=window)
    return jnp.moveaxis(o, 1, 2).reshape(B, S, KVH, G, D)
