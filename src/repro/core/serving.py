"""Policy serving subsystem: batched low-latency inference for live
traffic (survey §3.3 learner-side/centralized inference; SRL's
dedicated inference-worker class; Gorila's separation of acting from
learning).

Training (repro.core.trainer) owns throughput; this module owns
*latency under load*. It mirrors the Trainer seam on the traffic side:

  * **`serve_step`** — a jitted, donated micro-batch program per bucket
    size. One program evaluates `agent.actor_policy`-compatible
    behavior params on a `(bucket, *obs_shape)` request batch: each
    request's action/log-prob/value comes from ONE
    `policy.sample_value` evaluation keyed by `fold_in(base_key,
    request_id)`, so a response depends only on (engine seed, request
    id, params) — never on which other requests happened to share the
    micro-batch. The small device-resident stats carry (requests
    served / batches dispatched) is donated to its same-shaped output,
    Trainer-superstep style; params are NOT donated — they are shared
    by every in-flight batch and across `ParamStore` versions.

  * **`RequestBatcher`** — host-side FIFO admission queue. Requests
    are never dropped and never reordered: `take` returns the oldest
    admissible requests up to the micro-batch cap, and anything beyond
    the cap simply waits for the next dispatch (backpressure, exactly
    like `queue_push` refusing on full in repro.core.pipeline).

  * **Bucketed micro-batching** — a batch of B live requests is padded
    to the smallest registered bucket >= B (`bucket_for`), exactly the
    pad-to-bucket discipline of the kernels ops layer
    (kernels/advantages/ops.py pads B to a block multiple), so each
    bucket size compiles ONCE and `ServeEngine.compile_count` stays
    flat under live traffic whatever batch sizes the load produces.
    Within a fixed bucket the padded rows are bitwise-inert: row i of a
    bucket-of-B dispatch equals row i of a per-request (single-request,
    same-bucket) dispatch bit for bit — pinned per registered env spec
    in tests/test_serving.py. (Across *different* bucket sizes XLA may
    pick different matmul tilings, so cross-bucket equality is
    numerical, not bitwise — one more reason the bucket set is a small
    static grammar and not per-batch shapes.)

  * **`ParamStore`** — versioned zero-recompile param hot-swap. Params
    enter `serve_step` as traced inputs, so publishing new weights —
    from a Trainer fit, a `repro.checkpoint` archive, or the live
    actor-param ring via `agent.actor_policy` — never triggers
    recompilation; `publish` validates the new pytree against the
    first-published template (same treedef/shapes/dtypes) and raises
    before a silently recompiling swap can happen. Versions are
    monotonic; a dispatch reads `(version, params)` once at admission,
    so in-flight batches finish on the version they started with and
    every response is tagged with the version that produced it.

Offered-load latency/throughput is measured by
`repro.launch.serve_policy` -> repo-root BENCH_serve.json (p50/p99 at
varying offered load and bucket configurations), schema-guarded by
tests/test_bench_schema.py.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------- param store
class ParamStore:
    """Versioned behavior-param store for zero-recompile hot-swap.

    The first `publish` fixes the template (treedef + leaf
    shapes/dtypes); every later publish must match it exactly, which is
    what makes hot-swap recompile-free BY CONSTRUCTION — `serve_step`
    is traced once per bucket against the template's shapes and new
    versions only ever change buffer *contents*. `get()` hands out
    `(version, params)` as an immutable snapshot: publishing never
    mutates previously handed-out arrays, so in-flight batches finish
    on the version they started with.
    """

    def __init__(self):
        self._version = 0
        self._params = None
        self._template = None   # [(keypath, shape, dtype), ...]

    @property
    def version(self) -> int:
        """Monotonic version of the latest published params (0 = none)."""
        return self._version

    @staticmethod
    def _signature(params):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        leaves = [("/".join(str(p) for p in path), leaf.shape,
                   jnp.dtype(leaf.dtype)) for path, leaf in flat]
        return treedef, leaves

    def publish(self, params) -> int:
        """Swap in new behavior params; returns the new version.

        Raises ValueError naming the offending leaf if the pytree does
        not match the first-published template — shape drift would mean
        a recompile, which serving never allows."""
        params = jax.tree_util.tree_map(jnp.asarray, params)
        treedef, leaves = self._signature(params)
        if self._template is None:
            self._template = (treedef, leaves)
        else:
            t_def, t_leaves = self._template
            if treedef != t_def:
                raise ValueError(
                    f"hot-swap rejected: params treedef {treedef} does "
                    f"not match the published template {t_def}")
            for (path, shape, dtype), (tp, ts, td) in zip(leaves,
                                                          t_leaves):
                if (shape, dtype) != (ts, td):
                    raise ValueError(
                        f"hot-swap rejected: leaf {path!r} is "
                        f"{shape}/{dtype}, template has {ts}/{td} — "
                        f"shape/dtype drift would force a recompile")
        self._version += 1
        self._params = params
        return self._version

    def publish_from_state(self, agent, state, delay: int = 0) -> int:
        """Publish the live actor-param ring view: whatever
        `agent.actor_policy(state, delay)` serves the rollout engine
        (for DQN that includes the annealed exploration rate, so served
        actions match the live actors bitwise). A ZeRO-3 sharded
        TrainState (topology.ZeRO3Agent wrapper form) is reassembled to
        the replicated tree shape first, so the published pytree always
        matches the plan-independent template."""
        state = getattr(agent, "host_state", lambda s: s)(state)
        return self.publish(agent.actor_policy(state, delay))

    def load_checkpoint(self, path, agent, example_state=None,
                        delay: int = 0) -> int:
        """Restore a Trainer checkpoint (repro.checkpoint) and publish
        its actor-policy view. The agent must be constructed with the
        config (ring_size etc.) that produced the checkpoint; see
        checkpoint.load_train_state."""
        from repro.checkpoint.ckpt import load_train_state
        state, _ = load_train_state(path, agent, example=example_state)
        return self.publish_from_state(agent, state, delay)

    def get(self):
        """-> (version, params) snapshot of the latest publish."""
        if self._params is None:
            raise RuntimeError("ParamStore is empty: publish params "
                               "(publish / publish_from_state / "
                               "load_checkpoint) before serving")
        return self._version, self._params


# ----------------------------------------------------------- batching
def validate_buckets(buckets) -> Tuple[int, ...]:
    """Normalize/validate a bucket grammar: a strictly increasing tuple
    of positive micro-batch sizes. The largest bucket is the dispatch
    cap. Raises ValueError naming the offending entry."""
    buckets = tuple(int(b) for b in buckets)
    if not buckets:
        raise ValueError("empty bucket set: serving needs at least one "
                         "micro-batch size")
    for i, b in enumerate(buckets):
        if b <= 0:
            raise ValueError(f"bucket sizes must be positive, got {b}")
        if i and b <= buckets[i - 1]:
            raise ValueError(f"bucket sizes must be strictly "
                             f"increasing, got {buckets[i - 1]} "
                             f"before {b}")
    return buckets


def bucket_for(n: int, buckets) -> int:
    """Smallest registered bucket >= n (pad-to-bucket, ops-layer
    style). `n` above the largest bucket is a caller error — the
    batcher caps takes at max(buckets)."""
    if n <= 0:
        raise ValueError(f"cannot bucket an empty batch (n={n})")
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch of {n} exceeds the largest bucket "
                     f"{buckets[-1]}; take() must cap at it")


class RequestBatcher:
    """Host-side FIFO admission queue for asynchronous requests.

    `submit` assigns a monotonically increasing request id and records
    the arrival time (wall-clock by default; load generators pass
    their scheduled arrival so queueing delay is charged to latency).
    `take` pops the oldest <= `max_n` admissible requests — strictly
    FIFO, never dropping: requests beyond the cap stay queued for the
    next dispatch."""

    def __init__(self):
        self._queue = collections.deque()
        self._next_id = 0

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, obs, arrival: Optional[float] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append(
            {"id": rid, "obs": obs,
             "arrival": time.perf_counter() if arrival is None
             else arrival})
        return rid

    def next_arrival(self) -> Optional[float]:
        """Arrival time of the oldest queued request (None if empty)."""
        return self._queue[0]["arrival"] if self._queue else None

    def take(self, max_n: int, now: Optional[float] = None) -> List[dict]:
        """Pop up to `max_n` requests in FIFO order. With `now`, only
        requests that have arrived (arrival <= now) are admissible —
        and FIFO means a not-yet-arrived head blocks everything behind
        it, so replayed arrival schedules stay in order."""
        out = []
        while self._queue and len(out) < max_n:
            if now is not None and self._queue[0]["arrival"] > now:
                break
            out.append(self._queue.popleft())
        return out


# ------------------------------------------------------------- engine
class ServeEngine:
    """Batched low-latency inference driver — the Trainer seam's
    traffic-facing mirror (module doc).

    `policy` is any rollout-engine policy (`sample_value`), `obs_space`
    the env's observation Space (padding template), `store` the
    ParamStore the engine reads at every dispatch. One jitted, donated
    `serve_step` program exists per bucket size; `compile_count` counts
    traces (== XLA compiles) and stays flat under live traffic, batch
    size variation and param hot-swap once `warmup()` has run."""

    def __init__(self, policy, obs_space, buckets=(1, 4, 16),
                 store: Optional[ParamStore] = None, seed: int = 0):
        self.policy = policy
        self.obs_space = obs_space
        self.buckets = validate_buckets(buckets)
        self.store = ParamStore() if store is None else store
        self.batcher = RequestBatcher()
        self.results: Dict[int, dict] = {}
        self._base_key = jax.random.PRNGKey(seed)
        self._fns: Dict[int, Any] = {}
        self._compiles = 0
        # device-resident stats carry, donated through every dispatch
        self._sstate = {"served": jnp.zeros((), jnp.int32),
                        "batches": jnp.zeros((), jnp.int32)}

    @classmethod
    def for_agent(cls, agent, env, **kw):
        """Engine for a registered Agent: its rollout policy + the
        env's observation spec. Publish params separately
        (`store.publish_from_state(agent, state)`)."""
        return cls(agent.policy, env.spec.observation, **kw)

    # -- the jitted per-bucket program ---------------------------------
    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    @property
    def compile_count(self) -> int:
        """Number of serve_step traces so far (tracing is 1:1 with XLA
        compilation here — the zero-recompile pin in tests and
        BENCH_serve.json reads this)."""
        return self._compiles

    @property
    def stats(self) -> Dict[str, int]:
        """Host view of the donated device stats carry."""
        return {k: int(v) for k, v in self._sstate.items()}

    def _bucket_fn(self, bucket: int):
        if bucket in self._fns:
            return self._fns[bucket]
        policy = self.policy

        def serve_step(params, sstate, base_key, obs, ids, n_valid):
            # trace-time side effect: each execution of this Python
            # body is exactly one XLA compilation of this bucket
            self._compiles += 1

            def one(o, i):
                return policy.sample_value(
                    params, o, jax.random.fold_in(base_key, i))

            action, logp, value = jax.vmap(one)(obs, ids)
            sstate = {"served": sstate["served"] + n_valid,
                      "batches": sstate["batches"] + 1}
            return sstate, action, logp, value

        fn = jax.jit(serve_step, donate_argnums=(1,))
        self._fns[bucket] = fn
        return fn

    def _pad_rows(self, rows, ids, bucket: int):
        # assemble host-side in numpy: one H2D transfer per dispatch
        # instead of a flurry of tiny stack/pad device ops (the
        # micro-batch path is latency-critical)
        shape = self.obs_space.shape
        dtype = np.dtype(jnp.dtype(self.obs_space.dtype).name)
        obs = np.zeros((bucket,) + shape, dtype)
        for j, r in enumerate(rows):
            obs[j] = np.asarray(r)
        pad_ids = np.full((bucket,), -1, np.int32)
        pad_ids[:len(ids)] = np.asarray(ids, np.int32)
        return obs, pad_ids

    def eval_bucket(self, obs_rows, ids, bucket: int, params=None):
        """Run the bucket's serve_step on explicit rows/ids (padded to
        `bucket`), returning `(action, logp, value)` for the first
        len(obs_rows) rows. This IS the program `step()` dispatches —
        the bucket-parity tests use it as the per-request oracle (one
        request per call, same bucket)."""
        if params is None:
            _, params = self.store.get()
        if not (0 < len(obs_rows) <= bucket):
            raise ValueError(f"{len(obs_rows)} rows do not fit "
                             f"bucket {bucket}")
        obs, pids = self._pad_rows(obs_rows, ids, bucket)
        self._sstate, action, logp, value = self._bucket_fn(bucket)(
            params, self._sstate, self._base_key, obs, pids,
            jnp.int32(len(obs_rows)))
        n = len(obs_rows)
        return action[:n], logp[:n], value[:n]

    def warmup(self):
        """Compile every bucket program once (against the current
        params) so live traffic never pays a compile; returns the
        compile count, which stays flat from here on."""
        _, params = self.store.get()
        for b in self.buckets:
            self.eval_bucket([jnp.zeros(self.obs_space.shape,
                                        self.obs_space.dtype)],
                             [0], b, params=params)
        return self._compiles

    # -- the serving loop ----------------------------------------------
    def submit(self, obs, arrival: Optional[float] = None) -> int:
        """Enqueue one observation; returns its request id."""
        return self.batcher.submit(obs, arrival)

    def step(self, now: Optional[float] = None) -> List[dict]:
        """Admit one micro-batch (FIFO, up to the largest bucket, padded
        to the smallest fitting bucket), evaluate it on the current
        ParamStore version, and return the completed responses
        (`{"id", "action", "logp", "value", "version", "latency_s"}`,
        also recorded in `self.results`). Returns [] when nothing is
        admissible."""
        reqs = self.batcher.take(self.max_bucket, now=now)
        if not reqs:
            return []
        version, params = self.store.get()
        bucket = bucket_for(len(reqs), self.buckets)
        action, logp, value = self.eval_bucket(
            [r["obs"] for r in reqs], [r["id"] for r in reqs], bucket,
            params=params)
        action, logp, value = jax.device_get((action, logp, value))
        done = time.perf_counter()
        out = []
        for j, r in enumerate(reqs):
            resp = {"id": r["id"], "action": action[j],
                    "logp": float(logp[j]), "value": float(value[j]),
                    "version": version,
                    "latency_s": done - r["arrival"]}
            self.results[r["id"]] = resp
            out.append(resp)
        return out

    def drain(self) -> List[dict]:
        """Serve until the admission queue is empty (ignores arrival
        times — everything queued is admissible)."""
        out = []
        while len(self.batcher):
            out.extend(self.step())
        return out

    def serve(self, obs_batch) -> jnp.ndarray:
        """Synchronous convenience: submit a whole observation batch,
        drain it through bucketed micro-batches, and return the actions
        stacked in submission order."""
        ids = [self.submit(o) for o in obs_batch]
        self.drain()
        return jnp.stack([jnp.asarray(self.results[i]["action"])
                          for i in ids])
