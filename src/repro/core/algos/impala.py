"""IMPALA actor-learner with V-trace (survey §3.2/§6.1).

The defining property — *policy lag* between the behavior policy (actor
params) and target policy (learner params) — is first-class: the driver
keeps actor params a configurable number of updates behind, and V-trace
corrects for the lag. tests/test_impala.py shows uncorrected actor-critic
degrades under lag while V-trace does not (the survey's §6.1 claim).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.agent import PolicyGradientAgent, register
from repro.core.networks import make_policy
from repro.core.vtrace import vtrace, epsilon_correction
from repro.optim import adamw, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class IMPALA:
    policy: object
    gamma: float = 0.99
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    clip_rho: float = 1.0
    clip_c: float = 1.0
    use_vtrace: bool = True
    use_eps_correction: bool = False

    def loss(self, params, traj, bootstrap_obs):
        """traj: time-major {obs, action, logp(behavior), reward, done}."""
        T, B = traj["reward"].shape
        obs_flat = traj["obs"].reshape((-1,) + traj["obs"].shape[2:])
        act_flat = traj["action"].reshape((-1,)
                                          + traj["action"].shape[2:])
        logp_t, v_t, ent = self.policy.log_prob(params, obs_flat, act_flat)
        if self.use_eps_correction:
            logp_t = epsilon_correction(logp_t)
        logp_t = logp_t.reshape(T, B)
        v_t = v_t.reshape(T, B)
        ent = ent.reshape(T, B)
        _, boot = self.policy.apply(params, bootstrap_obs)
        discounts = self.gamma * (1.0 - traj["done"].astype(jnp.float32))
        if self.use_vtrace:
            log_rhos = logp_t - traj["logp"]
            vs, pg_adv = vtrace(jax.lax.stop_gradient(log_rhos), discounts,
                                traj["reward"],
                                jax.lax.stop_gradient(v_t), boot,
                                self.clip_rho, self.clip_c)
        else:  # naive on-policy targets computed from off-policy data
            def disc_ret(acc, xs):
                r, d = xs
                acc = r + d * acc
                return acc, acc
            _, vs = jax.lax.scan(disc_ret, boot,
                                 (traj["reward"], discounts),
                                 reverse=True)
            vs = jax.lax.stop_gradient(vs)
            vs_tp1 = jnp.concatenate([vs[1:], boot[None]], axis=0)
            pg_adv = jax.lax.stop_gradient(
                traj["reward"] + discounts * vs_tp1
                - jax.lax.stop_gradient(v_t))
        pg_loss = -jnp.mean(logp_t * pg_adv)
        vf_loss = jnp.mean(jnp.square(v_t - vs))
        return pg_loss + self.vf_coef * vf_loss \
            - self.ent_coef * jnp.mean(ent)

    @functools.partial(jax.jit, static_argnames=("self", "optimizer"))
    def learner_step(self, params, opt_state, traj, bootstrap_obs,
                     optimizer):
        loss, grads = jax.value_and_grad(self.loss)(params, traj,
                                                    bootstrap_obs)
        params, opt_state = optimizer.apply(params, opt_state, grads)
        return params, opt_state, loss


class IMPALAAgent(PolicyGradientAgent):
    """IMPALA behind the unified protocol. The Trainer's §6 delay
    schedule supplies the policy lag that V-trace corrects for."""

    def __init__(self, env, ring_size=1, total_iters=None, lr=1e-3,
                 hidden=(64, 64), max_grad_norm=1.0, policy="mlp",
                 trunk_kwargs=None, **algo_kwargs):
        self.policy = make_policy(env.spec, policy, hidden,
                                  **(trunk_kwargs or {}))
        self.algo = IMPALA(self.policy, **algo_kwargs)
        self.opt = clip_by_global_norm(adamw(lr), max_grad_norm)
        self.ring_size = ring_size


register("impala", IMPALAAgent)
