"""DQN with (prioritized) replay and target network — the Gorila/Ape-X
learner (survey §3.1). Actor and learner are separate jitted functions
so the driver can place them on different workers."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.replay import UniformReplay, PrioritizedReplay
from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class DQN:
    obs_dim: int
    n_actions: int
    hidden: tuple = (64, 64)
    gamma: float = 0.99
    target_update: int = 100
    double: bool = True
    prioritized: bool = True
    replay_capacity: int = 10000

    @property
    def replay(self):
        return (PrioritizedReplay(self.replay_capacity)
                if self.prioritized
                else UniformReplay(self.replay_capacity))

    # -- q network -----------------------------------------------------
    def init(self, key):
        sizes = (self.obs_dim,) + self.hidden + (self.n_actions,)
        ks = jax.random.split(key, len(sizes))
        net = [{"w": dense_init(ks[i], (sizes[i], sizes[i + 1])),
                "b": jnp.zeros((sizes[i + 1],))}
               for i in range(len(sizes) - 1)]
        return {"online": net,
                "target": jax.tree_util.tree_map(jnp.copy, net),
                "steps": jnp.zeros((), jnp.int32)}

    @staticmethod
    def q_values(net, obs):
        h = obs
        for lay in net[:-1]:
            h = jax.nn.relu(h @ lay["w"] + lay["b"])
        return h @ net[-1]["w"] + net[-1]["b"]

    # -- actor ----------------------------------------------------------
    def act(self, params, obs, key, epsilon):
        q = self.q_values(params["online"], obs)
        greedy = jnp.argmax(q, axis=-1)
        rand = jax.random.randint(key, greedy.shape, 0, self.n_actions)
        take_rand = jax.random.uniform(key, greedy.shape) < epsilon
        return jnp.where(take_rand, rand, greedy)

    # -- learner ---------------------------------------------------------
    def td_errors(self, params, batch):
        q = self.q_values(params["online"], batch["obs"])
        qa = jnp.take_along_axis(q, batch["action"][..., None].astype(
            jnp.int32), -1)[..., 0]
        qn_t = self.q_values(params["target"], batch["next_obs"])
        if self.double:
            qn_o = self.q_values(params["online"], batch["next_obs"])
            a_star = jnp.argmax(qn_o, axis=-1)
            q_next = jnp.take_along_axis(qn_t, a_star[..., None],
                                         -1)[..., 0]
        else:
            q_next = qn_t.max(axis=-1)
        target = batch["reward"] + self.gamma * (
            1.0 - batch["done"].astype(jnp.float32)) * q_next
        return jax.lax.stop_gradient(target) - qa

    def loss(self, params, batch, is_weights=None):
        td = self.td_errors(params, batch)
        w = jnp.ones_like(td) if is_weights is None else is_weights
        return jnp.mean(w * jnp.square(td)), td

    @functools.partial(jax.jit, static_argnames=("self", "optimizer"))
    def learner_step(self, params, opt_state, replay_state, key,
                     optimizer, batch_size=64):
        if self.prioritized:
            batch, idx, w = self.replay.sample(replay_state, key,
                                               batch_size)
        else:
            batch, idx = self.replay.sample(replay_state, key, batch_size)
            w = None
        def loss_online(online):
            return self.loss(dict(params, online=online), batch, w)

        (loss, td), grads = jax.value_and_grad(
            loss_online, has_aux=True)(params["online"])
        online, opt_state = optimizer.apply(params["online"], opt_state,
                                            grads)
        params = dict(params, online=online)
        if self.prioritized:
            replay_state = self.replay.update_priorities(replay_state,
                                                         idx, td)
        steps = params["steps"] + 1
        target = jax.tree_util.tree_map(
            lambda t, o: jnp.where(steps % self.target_update == 0, o, t),
            params["target"], params["online"])
        params = dict(params, steps=steps, target=target)
        return params, opt_state, replay_state, loss
