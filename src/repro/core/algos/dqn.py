"""DQN with (prioritized) replay and target network — the Gorila/Ape-X
learner (survey §3.1). Actor and learner are separate jitted functions
so the driver can place them on different workers."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.agent import Agent, TrainState, register
from repro.core.replay import UniformReplay, PrioritizedReplay
from repro.models.layers import dense_init
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class DQN:
    obs_dim: int
    n_actions: int
    hidden: tuple = (64, 64)
    gamma: float = 0.99
    target_update: int = 100
    double: bool = True
    prioritized: bool = True
    replay_capacity: int = 10000
    fused_sampling: bool = True  # Gumbel-top-k kernel path (replay.py);
    #                              False = legacy categorical escape
    #                              hatch (WITH replacement). Default
    #                              since the kernel parity pin of PR 3.
    net: object = None  # pluggable q-net adapter (init/apply -> (q, _));
    #                     None = the house MLP below. Lets the trunk
    #                     policy (networks.TrunkPolicy) serve as q-net.

    @property
    def replay(self):
        return (PrioritizedReplay(self.replay_capacity,
                                  fused=self.fused_sampling)
                if self.prioritized
                else UniformReplay(self.replay_capacity))

    # -- q network -----------------------------------------------------
    def init(self, key):
        if self.net is not None:
            net = self.net.init(key)
        else:
            sizes = (self.obs_dim,) + self.hidden + (self.n_actions,)
            ks = jax.random.split(key, len(sizes))
            net = [{"w": dense_init(ks[i], (sizes[i], sizes[i + 1])),
                    "b": jnp.zeros((sizes[i + 1],))}
                   for i in range(len(sizes) - 1)]
        return {"online": net,
                "target": jax.tree_util.tree_map(jnp.copy, net),
                "steps": jnp.zeros((), jnp.int32)}

    def q_values(self, net, obs):
        if self.net is not None:
            return self.net.apply(net, obs)[0]
        h = obs
        for lay in net[:-1]:
            h = jax.nn.relu(h @ lay["w"] + lay["b"])
        return h @ net[-1]["w"] + net[-1]["b"]

    # -- actor ----------------------------------------------------------
    def act(self, params, obs, key, epsilon):
        q = self.q_values(params["online"], obs)
        greedy = jnp.argmax(q, axis=-1)
        rand = jax.random.randint(key, greedy.shape, 0, self.n_actions)
        take_rand = jax.random.uniform(key, greedy.shape) < epsilon
        return jnp.where(take_rand, rand, greedy)

    # -- learner ---------------------------------------------------------
    def td_errors(self, params, batch):
        q = self.q_values(params["online"], batch["obs"])
        qa = jnp.take_along_axis(q, batch["action"][..., None].astype(
            jnp.int32), -1)[..., 0]
        qn_t = self.q_values(params["target"], batch["next_obs"])
        if self.double:
            qn_o = self.q_values(params["online"], batch["next_obs"])
            a_star = jnp.argmax(qn_o, axis=-1)
            q_next = jnp.take_along_axis(qn_t, a_star[..., None],
                                         -1)[..., 0]
        else:
            q_next = qn_t.max(axis=-1)
        target = batch["reward"] + self.gamma * (
            1.0 - batch["done"].astype(jnp.float32)) * q_next
        return jax.lax.stop_gradient(target) - qa

    def loss(self, params, batch, is_weights=None):
        td = self.td_errors(params, batch)
        w = jnp.ones_like(td) if is_weights is None else is_weights
        return jnp.mean(w * jnp.square(td)), td

    @functools.partial(jax.jit, static_argnames=("self", "optimizer"))
    def learner_step(self, params, opt_state, replay_state, key,
                     optimizer, batch_size=64):
        if self.prioritized:
            batch, idx, w = self.replay.sample(replay_state, key,
                                               batch_size)
        else:
            batch, idx = self.replay.sample(replay_state, key, batch_size)
            w = None
        def loss_online(online):
            return self.loss(dict(params, online=online), batch, w)

        (loss, td), grads = jax.value_and_grad(
            loss_online, has_aux=True)(params["online"])
        online, opt_state = optimizer.apply(params["online"], opt_state,
                                            grads)
        params = dict(params, online=online)
        if self.prioritized:
            replay_state = self.replay.update_priorities(replay_state,
                                                         idx, td)
        steps = params["steps"] + 1
        target = jax.tree_util.tree_map(
            lambda t, o: jnp.where(steps % self.target_update == 0, o, t),
            params["target"], params["online"])
        params = dict(params, steps=steps, target=target)
        return params, opt_state, replay_state, loss


class _QPolicy:
    """Adapter exposing a DQN net to the shared rollout engine: behavior
    params are {"net": online-net, "eps": exploration rate} so ε rides
    through `actor_policy` and the rollout stays algorithm-agnostic."""

    discrete = True

    def __init__(self, dqn: DQN):
        self.dqn = dqn

    def apply(self, params, obs):
        q = self.dqn.q_values(params["net"], obs)
        return q, q.max(axis=-1)

    def sample(self, params, obs, key):
        a = self.dqn.act({"online": params["net"]}, obs, key,
                         params["eps"])
        q = self.dqn.q_values(params["net"], obs)
        logp = jnp.take_along_axis(jax.nn.log_softmax(q),
                                   a[..., None], -1)[..., 0]
        return a, logp

    def sample_value(self, params, obs, key):
        """ε-greedy draw + log-prob + value from ONE q evaluation (the
        sample/apply pair evaluated the net three times); same key
        discipline as DQN.act, so actions are bitwise unchanged."""
        q = self.dqn.q_values(params["net"], obs)
        greedy = jnp.argmax(q, axis=-1)
        rand = jax.random.randint(key, greedy.shape, 0,
                                  self.dqn.n_actions)
        take_rand = jax.random.uniform(key, greedy.shape) < params["eps"]
        a = jnp.where(take_rand, rand, greedy)
        logp = jnp.take_along_axis(jax.nn.log_softmax(q),
                                   a[..., None], -1)[..., 0]
        return a, logp, q.max(axis=-1)


class DQNAgent(Agent):
    """DQN/Ape-X behind the unified protocol: the rollout trajectory is
    flattened into transitions and pushed into a per-worker on-device
    replay carried inside TrainState.extra; one (prioritized) TD update
    runs per iteration after `warmup` iterations of pure collection."""

    def __init__(self, env, ring_size=1, total_iters=None, lr=1e-3,
                 hidden=(64, 64), prioritized=True, replay_capacity=20000,
                 batch_size=64, warmup=8, eps_start=1.0, eps_end=0.05,
                 eps_decay_steps=None, policy="mlp", trunk_kwargs=None,
                 **algo_kwargs):
        spec = env.spec
        self.obs_space = spec.observation
        net = None
        if policy == "trunk":
            from repro.core.networks import TrunkPolicy
            net = TrunkPolicy.for_spec(spec, **(trunk_kwargs or {}))
        elif policy != "mlp":
            raise ValueError(f"unknown policy {policy!r}: expected "
                             f"'mlp' or 'trunk'")
        self.dqn = DQN(spec.obs_dim, spec.n_actions, hidden=tuple(hidden),
                       prioritized=prioritized,
                       replay_capacity=replay_capacity, net=net,
                       **algo_kwargs)
        self.policy = _QPolicy(self.dqn)
        # the Trainer swaps this for a ShardedPrioritizedReplay when its
        # DistPlan carries an active replay-role axis; init() keeps the
        # flat host form either way (plan-independent checkpoints)
        self.replay = self.dqn.replay
        self.opt = adamw(lr)
        self.ring_size = ring_size
        self.batch_size = batch_size
        self.warmup = warmup
        self.eps_start = eps_start
        self.eps_end = eps_end
        if eps_decay_steps is None:  # anneal over 60% of the run
            eps_decay_steps = max(1, int(0.6 * total_iters)) \
                if total_iters else 200
        self.eps_decay_steps = eps_decay_steps

    def init(self, key):
        params = self.dqn.init(key)
        obs_zero = jnp.zeros(self.obs_space.shape,
                             self.obs_space.dtype)
        example = {"obs": obs_zero,
                   "action": jnp.zeros((), jnp.int32),
                   "reward": jnp.zeros(()),
                   "next_obs": obs_zero,
                   "done": jnp.zeros((), bool)}
        return TrainState(params, self.opt.init(params["online"]),
                          {"replay": self.dqn.replay.init(example)},
                          self._ring_init(params["online"]),
                          jnp.zeros((), jnp.int32))

    def partition_spec(self, state):
        """Only the online net is optimizer-updated (opt_state mirrors
        it); target net + step counter ride outside the shard."""
        return state.params["online"]

    def replace_partition(self, params, sub):
        return dict(params, online=sub)

    def actor_policy(self, state, delay=0):
        frac = jnp.clip(state.steps.astype(jnp.float32)
                        / self.eps_decay_steps, 0.0, 1.0)
        eps = self.eps_start + frac * (self.eps_end - self.eps_start)
        return {"net": self._ring_read(state.ring, delay), "eps": eps}

    def learner_step(self, state, traj, boot_obs, key,
                     grad_tx=None, param_tx=None):
        # traj -> transitions; the rollout surfaces the TRUE successor
        # obs (pre-autoreset at episode boundaries), so replayed
        # transitions are exact even across resets.
        flat = lambda a: a.reshape((-1,) + a.shape[2:])
        transitions = {"obs": flat(traj["obs"]),
                       "action": flat(traj["action"]).astype(jnp.int32),
                       "reward": flat(traj["reward"]),
                       "next_obs": flat(traj["next_obs"]),
                       "done": flat(traj["done"])}
        replay = self.replay
        rstate = replay.add_batch(state.extra["replay"], transitions)

        if self.dqn.prioritized:
            batch, idx, w = replay.sample(rstate, key, self.batch_size)
        else:
            batch, idx = replay.sample(rstate, key, self.batch_size)
            w = None

        def loss_online(online):
            return self.dqn.loss(dict(state.params, online=online),
                                 batch, w)

        (loss, td), grads = jax.value_and_grad(
            loss_online, has_aux=True)(state.params["online"])
        if grad_tx is not None:
            grads = grad_tx(grads)
        online, opt_state = self.opt.apply(state.params["online"],
                                           state.opt_state, grads)
        if param_tx is not None:
            online = param_tx(online)
        warm = state.steps >= self.warmup
        if self.dqn.prioritized:
            # keep the Ape-X max-priority inserts during warmup — |td|
            # from the untrained net would under-prioritize early data
            updated = replay.update_priorities(rstate, idx, td)
            rstate = dict(rstate, prio=jnp.where(warm, updated["prio"],
                                                 rstate["prio"]))
        qsteps = state.params["steps"] + 1
        target = jax.tree_util.tree_map(
            lambda t, o: jnp.where(qsteps % self.dqn.target_update == 0,
                                   o, t),
            state.params["target"], online)
        new_params = {"online": online, "target": target, "steps": qsteps}
        # pure-collection warmup: keep filling the replay, hold the params
        sel = lambda new, old: jax.tree_util.tree_map(
            lambda a, b: jnp.where(warm, a, b), new, old)
        params = sel(new_params, state.params)
        opt_state = sel(opt_state, state.opt_state)
        return TrainState(params, opt_state, {"replay": rstate},
                          self._ring_push(state.ring, params["online"]),
                          state.steps + 1), {"loss": jnp.where(warm, loss,
                                                               0.0)}


register("dqn", DQNAgent)
