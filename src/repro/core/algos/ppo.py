"""PPO with GAE; DD-PPO mode = decentralized synchronous gradient
exchange over a worker axis (survey §3.2 / §6.2)."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.advantages import gae
from repro.core.agent import PolicyGradientAgent, TrainState, register
from repro.core.networks import make_policy
from repro.optim import adamw, clip_by_global_norm

__all__ = ["gae", "PPO", "PPOAgent"]  # gae re-exported for back-compat


@dataclasses.dataclass(frozen=True)
class PPO:
    policy: object
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    gamma: float = 0.99
    lam: float = 0.95

    def loss(self, params, batch):
        """batch: flattened {obs, action, logp, adv, ret}."""
        logp, v, ent = self.policy.log_prob(params, batch["obs"],
                                            batch["action"])
        ratio = jnp.exp(logp - batch["logp"])
        adv = batch["adv"]
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
        unclipped = ratio * adv
        clipped = jnp.clip(ratio, 1 - self.clip_eps,
                           1 + self.clip_eps) * adv
        pg = -jnp.mean(jnp.minimum(unclipped, clipped))
        vf = jnp.mean(jnp.square(v - batch["ret"]))
        return pg + self.vf_coef * vf - self.ent_coef * jnp.mean(ent)

    def make_batch(self, params, traj, last_obs):
        """traj: time-major rollout dict. Computes GAE (through the
        core.advantages kernel seam — Pallas on TPU, scan ref
        elsewhere) and flattens."""
        _, boot = self.policy.apply(params, last_obs)
        adv, ret = gae(traj["reward"], traj["value"], traj["done"], boot,
                       self.gamma, self.lam, use_kernel=True)
        flat = lambda a: a.reshape((-1,) + a.shape[2:])
        return {"obs": flat(traj["obs"]), "action": flat(traj["action"]),
                "logp": flat(traj["logp"]), "adv": flat(adv),
                "ret": flat(ret)}

    @functools.partial(jax.jit, static_argnames=("self", "optimizer",
                                                 "n_epochs", "n_minibatch"))
    def update(self, params, opt_state, batch, key, optimizer,
               n_epochs=4, n_minibatch=4):
        n = batch["obs"].shape[0]
        mb = n // n_minibatch

        def epoch(carry, key_e):
            params, opt_state = carry
            perm = jax.random.permutation(key_e, n)

            def minibatch(carry, i):
                params, opt_state = carry
                idx = jax.lax.dynamic_slice_in_dim(perm, i * mb, mb)
                mbatch = jax.tree_util.tree_map(lambda a: a[idx], batch)
                loss, grads = jax.value_and_grad(self.loss)(params, mbatch)
                params, opt_state = optimizer.apply(params, opt_state,
                                                    grads)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                minibatch, (params, opt_state), jnp.arange(n_minibatch))
            return (params, opt_state), losses.mean()

        (params, opt_state), losses = jax.lax.scan(
            epoch, (params, opt_state), jax.random.split(key, n_epochs))
        return params, opt_state, losses.mean()


class PPOAgent(PolicyGradientAgent):
    """PPO behind the unified protocol (shares init with the other
    policy-gradient agents; the learner is its own epoch/minibatch
    scan). With n_workers > 1 the Trainer's grad_tx all-reduces every
    minibatch gradient — DD-PPO's decentralized synchronous exchange
    (survey §3.2)."""

    def __init__(self, env, ring_size=1, total_iters=None, lr=3e-4,
                 hidden=(64, 64), n_epochs=4, n_minibatch=4,
                 max_grad_norm=0.5, policy="mlp", trunk_kwargs=None,
                 **algo_kwargs):
        self.policy = make_policy(env.spec, policy, hidden,
                                  **(trunk_kwargs or {}))
        self.algo = PPO(self.policy, **algo_kwargs)
        self.opt = clip_by_global_norm(adamw(lr), max_grad_norm)
        self.n_epochs = n_epochs
        self.n_minibatch = n_minibatch
        self.ring_size = ring_size

    def learner_step(self, state, traj, boot_obs, key,
                     grad_tx=None, param_tx=None):
        batch = self.algo.make_batch(state.params, traj, boot_obs)
        n = batch["obs"].shape[0]
        mb = n // self.n_minibatch

        def epoch(carry, key_e):
            params, opt_state = carry
            perm = jax.random.permutation(key_e, n)

            def minibatch(carry, i):
                params, opt_state = carry
                idx = jax.lax.dynamic_slice_in_dim(perm, i * mb, mb)
                mbatch = jax.tree_util.tree_map(lambda a: a[idx], batch)
                loss, grads = jax.value_and_grad(self.algo.loss)(params,
                                                                 mbatch)
                if grad_tx is not None:
                    grads = grad_tx(grads)
                params, opt_state = self.opt.apply(params, opt_state,
                                                   grads)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                minibatch, (params, opt_state),
                jnp.arange(self.n_minibatch))
            return (params, opt_state), losses.mean()

        (params, opt_state), losses = jax.lax.scan(
            epoch, (state.params, state.opt_state),
            jax.random.split(key, self.n_epochs))
        if param_tx is not None:
            params = param_tx(params)
        return TrainState(params, opt_state, state.extra,
                          self._ring_push(state.ring, params),
                          state.steps + 1), {"loss": losses.mean()}


register("ppo", PPOAgent)
