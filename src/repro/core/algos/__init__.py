from repro.core.algos.dqn import DQN, DQNAgent  # noqa: F401
from repro.core.algos.ppo import PPO, PPOAgent  # noqa: F401
from repro.core.algos.impala import IMPALA, IMPALAAgent  # noqa: F401
from repro.core.algos.a3c import A3C, A3CAgent  # noqa: F401
