from repro.core.algos.dqn import DQN  # noqa: F401
from repro.core.algos.ppo import PPO  # noqa: F401
from repro.core.algos.impala import IMPALA  # noqa: F401
from repro.core.algos.a3c import A3C  # noqa: F401
