"""A3C (survey §3.1/Fig. 4c): asynchronous advantage actor-critic.

SPMD adaptation: the async actor-learner threads are modeled with the
deterministic staleness engine (core.sync) — each simulated thread
accumulates n-step actor-critic gradients against a stale copy of the
global network and applies them Hogwild-style (sequentially, which is
the reproducible rendering of lock-free updates)."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.advantages import nstep_return
from repro.core.agent import PolicyGradientAgent, register
from repro.core.networks import make_policy
from repro.optim import adamw, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class A3C:
    policy: object
    gamma: float = 0.99
    vf_coef: float = 0.5
    ent_coef: float = 0.01

    def loss(self, params, traj, bootstrap_obs):
        """n-step returns from a time-major on-policy trajectory."""
        T, B = traj["reward"].shape
        obs_flat = traj["obs"].reshape((-1,) + traj["obs"].shape[2:])
        act_flat = traj["action"].reshape((-1,)
                                          + traj["action"].shape[2:])
        logp, v, ent = self.policy.log_prob(params, obs_flat, act_flat)
        logp, v, ent = (a.reshape(T, B) for a in (logp, v, ent))
        _, boot = self.policy.apply(params, bootstrap_obs)
        # n-step targets through the core.advantages kernel seam
        # (Pallas reverse-scan on TPU, lax.scan ref elsewhere)
        ret = nstep_return(traj["reward"], traj["done"], boot,
                           self.gamma, use_kernel=True)
        adv = jax.lax.stop_gradient(ret - v)
        return (-jnp.mean(logp * adv)
                + self.vf_coef * jnp.mean(jnp.square(v - ret))
                - self.ent_coef * jnp.mean(ent))

    @functools.partial(jax.jit, static_argnames=("self", "optimizer",
                                                 "n_threads"))
    def hogwild_update(self, params, opt_state, trajs, boot_obs,
                       delays_params, optimizer, n_threads):
        """Apply n_threads gradient contributions sequentially; thread i
        computed its gradient against `delays_params[i]` (stale copies).
        trajs: pytree with leading thread dim."""
        def body(carry, xs):
            params, opt_state = carry
            traj_i, boot_i, stale_i = xs
            _, grads = jax.value_and_grad(self.loss)(stale_i, traj_i,
                                                     boot_i)
            params, opt_state = optimizer.apply(params, opt_state, grads)
            return (params, opt_state), None

        (params, opt_state), _ = jax.lax.scan(
            body, (params, opt_state), (trajs, boot_obs, delays_params))
        return params, opt_state


class A3CAgent(PolicyGradientAgent):
    """A3C behind the unified protocol. Its defining asynchrony is not
    re-implemented here: run it under the Trainer with `sync="asp"` and
    the delay schedule makes each worker compute n-step actor-critic
    gradients against a stale copy of the network — the deterministic
    rendering of Hogwild-style lock-free updates."""

    def __init__(self, env, ring_size=1, total_iters=None, lr=1e-3,
                 hidden=(64, 64), max_grad_norm=1.0, policy="mlp",
                 trunk_kwargs=None, **algo_kwargs):
        self.policy = make_policy(env.spec, policy, hidden,
                                  **(trunk_kwargs or {}))
        self.algo = A3C(self.policy, **algo_kwargs)
        self.opt = clip_by_global_norm(adamw(lr), max_grad_norm)
        self.ring_size = ring_size


register("a3c", A3CAgent)
