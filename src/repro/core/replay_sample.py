"""Fused prioritized replay sampling — public API.

Mirrors core/vtrace.py: dispatches to the Pallas Gumbel-top-k kernel on
TPU and the jnp reference elsewhere; both share the oracle in
kernels/replay_sample/ref.py. `PrioritizedReplay(fused=True)` samples
through this seam.
"""
from repro.kernels.common import interpret_mode
from repro.kernels.replay_sample.ref import (prioritized_sample_ref,
                                             shard_gumbel_topk_ref)


def fused_prioritized_sample(prio, size, gumbel, n, alpha=0.6, beta=0.4,
                             eps=1e-6, use_kernel=False):
    """prio (C,), size scalar, gumbel (C,) ~ Gumbel(0,1), n draws
    WITHOUT replacement ∝ p_i^α. Returns (idx (n,) i32, w (n,) f32)."""
    if use_kernel and not interpret_mode():
        from repro.kernels.replay_sample.ops import prioritized_sample
        return prioritized_sample(prio, size, gumbel, n, alpha, beta, eps)
    return prioritized_sample_ref(prio, size, gumbel, n, alpha, beta, eps)


def shard_gumbel_topk(prio, nvalid, gumbel, k, alpha=0.6, eps=1e-6,
                      use_kernel=False):
    """Per-shard candidate draw of the sharded replay service: top-k
    (score, local index) pairs over ONE shard's (chunk,) priority slice.
    `nvalid` is the shard-LOCAL valid count (the global max(size, 1)
    guard stays with the service). Kernel and ref agree bitwise — the
    seam mirrors fused_prioritized_sample."""
    if use_kernel and not interpret_mode():
        from repro.kernels.replay_sample.ops import shard_topk
        return shard_topk(prio, nvalid, gumbel, k, alpha, eps)
    return shard_gumbel_topk_ref(prio, nvalid, gumbel, k, alpha, eps)
