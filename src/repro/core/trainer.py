"""Unified Trainer: one fused driver executing a declarative DistPlan.

Composes the survey's three acceleration axes over any registered Agent
(repro.core.agent); *how* the run is distributed is no longer a flat
`topology: str` over one worker axis but a `repro.core.distribution.
DistPlan` — a hierarchy of named mesh axes, each with its own
collective (§3) and sync discipline (§6):

  * batch simulation (§4.2): the shared rollout engine fuses env
    dynamics + policy inference into the training program;
  * system topology (§3, Fig. 3): with a multi-device plan the whole
    iteration runs per-device inside a `shard_map` over the plan's
    mesh; the plan compiles per-axis collectives into the
    `grad_tx`/`param_tx` hooks (e.g. intra-host allreduce + inter-host
    gossip);
  * synchronization (§6, Fig. 6): per-axis bsp/asp/ssp render as a
    deterministic policy-lag schedule (`plan.make_delay_schedule`)
    whose per-axis delays ADD, indexing each agent's actor-param ring;
  * elastic actors (ElegantRL-Podracer): `plan.actors` varies the env
    shard count between supersteps — agents only consume `traj`, so
    `fit` reshards the simulation carry host-side and the agents never
    see the change;
  * sharded learner states (§5 memory ceiling, ZeRO-2): a `shard`-role
    axis partitions the agent's optimizer state 1/N per device
    (`topology.zero_sharded_optimizer`): gradients reduce-scatter over
    the axis (the pmean half fuses into `grad_tx`), the per-coordinate
    update runs on the local flattened slice, and params all-gather
    before the next rollout — f32-bitwise the replicated plan, and a
    size-1 shard axis is a bitwise no-op;
  * sharded replay memory (§3, Gorila's Replay Memory): a `replay`-role
    axis turns the agent's prioritized buffer into ONE logical buffer
    over the axis (`repro.core.replay_service`), 1/N capacity per
    member. The group replicates its data position's rollout/learner
    compute (envs, RNG streams and grad/metric collectives all range
    over the non-replay "sim grid"), so the axis adds replay capacity
    — not sample throughput — and the fit stays f32-bitwise the flat
    data plan; a size-1 replay axis is left unwrapped (a data axis by
    construction).

`fit(fused=True)` scans `superstep` iterations (rollout -> learner_step
-> lag-ring rotate) inside ONE jitted `lax.scan`: the Python loop
dispatches iters/K programs and reads metrics back once per superstep
instead of blocking on `float(...)` every iteration.  `fit(fused=False)`
runs the identical iteration body one step at a time — numerically
equivalent (tests/test_trainer.py) but host-bound; the speedup is
measured in benchmarks/fused_superstep.py.

**Pipelined mode** (`TrainerConfig.pipeline=True`, the survey §2
actor/learner decoupling — Gorila/Ape-X, SRL's description/execution
split): the superstep body is split at the trajectory seam into a
rollout *producer* and a learner *consumer* joined by a fixed-capacity
device-resident trajectory queue (repro.core.pipeline) riding in the
carry. The queue depth is what the plan's per-axis sync discipline
admits (`DistPlan.pipeline_depth`): bsp -> 0, ssp -> staleness_bound,
asp -> max_delay. At depth 0 the tick degenerates to push-then-pop
through one slot — lockstep, f32-bitwise the fused path (pinned in
tests/test_pipeline.py). At depth >= 1 the producer runs `depth`
iterations AHEAD: tick t pops the trajectory produced at tick t-depth
(no data dependency on this tick's rollout) and produces the
trajectory for iteration t+depth, so XLA's scheduler is free to
execute simulation of iteration t+depth concurrently with the learner
update of iteration t — the staleness the fused path only *models* as
sampled policy-lag delays becomes real overlapped compute, with the
actor-param ring supplying the lagged policy. Ticks are unrolled (not
scanned): scan bodies execute serially, which would hide the
producer/consumer independence from the scheduler. Walltime overlap is
measured in benchmarks/pipeline_overlap.py -> BENCH_pipeline.json.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import agent as agent_api
from repro.core.agent import flatten_and_pad
from repro.core.distribution import DistPlan
from repro.core.pipeline import queue_init, queue_pop, queue_push
from repro.core.rollout import rollout
from repro.core.topology import (ZeRO3Agent, replicate_for,
                                 restore_worker_dim, strip_worker_dim,
                                 zero_sharded_optimizer)


@dataclasses.dataclass
class TrainerConfig:
    algo: str = "impala"
    iters: int = 60
    superstep: int = 10        # K iterations fused per jitted dispatch
    n_envs: int = 32           # total envs (split across devices)
    unroll: int = 32           # rollout length T per iteration
    plan: Optional[DistPlan] = None  # distribution plan; None = 1 worker
    policy_lag: int = 0        # deterministic actor-param lag floor
    seed: int = 0
    log_every: int = 10
    donate: bool = True        # zero-copy supersteps: donate state/sim
    pipeline: bool = False     # decoupled actor-learner trajectory queue
    algo_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def resolved_plan(self) -> DistPlan:
        return self.plan if self.plan is not None else DistPlan.flat()

    @property
    def ring_size(self) -> int:
        """Actor-param history depth the plan's sync hierarchy can reach
        into (per-axis staleness adds)."""
        return self.policy_lag + self.resolved_plan().ring_extra + 1


class Trainer:
    """Drives any registered Agent under a DistPlan; see module doc."""

    def __init__(self, env, cfg: TrainerConfig):
        plan = cfg.resolved_plan()
        # envs shard over the SIMULATION grid (an active replay-role
        # axis replicates rollouts — it adds replay capacity, not
        # sample throughput), so divisibility is against sim_devices
        if cfg.n_envs % plan.sim_devices:
            raise ValueError(f"n_envs={cfg.n_envs} must divide evenly "
                             f"across the plan's {plan.sim_devices} "
                             f"simulation devices (mesh "
                             f"{plan.mesh_shape}, env grid "
                             f"{plan.sim_shape})")
        if plan.actors is not None:
            bad = [n for n in plan.actors if n % plan.sim_devices]
            if bad:
                raise ValueError(
                    f"actors= schedule entries {bad} must divide evenly "
                    f"across the plan's {plan.sim_devices} simulation "
                    f"devices")
        if cfg.pipeline and plan.actors is not None \
                and len(set(plan.actors)) > 1:
            raise ValueError(
                f"pipeline=True cannot combine with a varying elastic "
                f"actors= schedule {plan.actors}: the trajectory queue's "
                f"buffer shape is fixed per compile, so in-flight "
                f"trajectories cannot be resharded — use a constant "
                f"schedule or fused mode")
        self.env = env
        self.cfg = cfg
        self.plan = plan
        self.agent = agent_api.make(cfg.algo, env=env,
                                    ring_size=cfg.ring_size,
                                    total_iters=cfg.iters,
                                    **cfg.algo_kwargs)
        # ZeRO-2 learner-state sharding (shard-role axis): the agent's
        # optimizer state lives 1/N per device over the shard axis; the
        # wrapper reduce-scatters (pmean fused into grad_tx + local
        # slice), updates the slice, and all-gathers params. A size-1
        # shard axis is left unwrapped: sharding into one chunk is the
        # identity, so the axis degenerates to a data axis and the
        # bitwise no-op guarantee holds BY CONSTRUCTION (same program
        # as the nested data-plan parity pinned in tests).
        self.partition = None    # populated by _init_all when sharded
        self._part_unravel = None
        self._part_unravels = None
        shard = plan.shard_axis
        self._sharded = (shard is not None and shard.size > 1
                         and plan.n_devices > 1)
        # full ZeRO-3 (zero3-role axis): params stored sharded too and
        # gathered per use; executed by wrapping the agent below
        self._zero3 = self._sharded and shard.role == "zero3"
        if self._zero3 and cfg.pipeline:
            raise ValueError(
                f"pipeline=True cannot combine with the zero3-role axis "
                f"{shard.name!r}: the trajectory queue's item template "
                f"is shape-traced outside the mesh program, where the "
                f"gather-per-use actor params have no axis environment "
                f"— use role 'shard' (ZeRO-2) or fused mode")
        if self._sharded and not hasattr(self.agent, "opt"):
            raise ValueError(
                f"algorithm {cfg.algo!r} exposes no `.opt` optimizer — "
                f"required to execute the shard-role axis "
                f"{shard.name!r} (ZeRO learner-state sharding)")
        # sharded replay service (replay-role axis): the agent's
        # prioritized buffer becomes ONE logical buffer over the axis,
        # 1/N capacity per member, behind the same add_batch/sample/
        # update_priorities interface. A size-1 replay axis is left
        # unwrapped — it degenerates to a data axis and the bitwise
        # no-op guarantee holds BY CONSTRUCTION (sim grid, RNG streams
        # and collectives all treat it as data).
        rax = plan.replay_axis
        self._replay = (rax is not None and rax.size > 1
                        and plan.n_devices > 1)
        if self._replay and cfg.pipeline:
            raise ValueError(
                f"pipeline=True cannot combine with the replay-role "
                f"axis {rax.name!r}: the decoupled superstep reorders "
                f"the add_batch/sample interleaving against the "
                f"sharded buffer and that combination has no validated "
                f"parity — use the fused superstep (pipeline=False) or "
                f"drop the replay axis")
        self._replay_service = None
        self.partition_replay = None
        if self._replay:
            from repro.core.replay import PrioritizedReplay
            from repro.core.replay_service import ShardedPrioritizedReplay
            flat_replay = getattr(self.agent, "replay", None)
            if not isinstance(flat_replay, PrioritizedReplay):
                raise ValueError(
                    f"replay axis {rax.name!r}: algorithm {cfg.algo!r} "
                    f"does not carry a PrioritizedReplay on its learner "
                    f"hot path (agent.replay) — the sharded replay "
                    f"service backs that seam only (DQN; ERL's "
                    f"evolutionary buffer rides its own loop)")
            if not flat_replay.fused:
                raise ValueError(
                    f"replay axis {rax.name!r}: the sharded replay "
                    f"service decomposes the fused Gumbel-top-k draw "
                    f"per shard; the legacy categorical path "
                    f"(fused_sampling=False) has no such decomposition "
                    f"— drop fused_sampling=False or the replay axis")
            # capacity % axis size raises here, naming the axis
            self._replay_service = ShardedPrioritizedReplay(
                flat_replay.capacity, rax.name, rax.size,
                alpha=flat_replay.alpha, beta=flat_replay.beta,
                eps=flat_replay.eps)
            # swap the seam on the RAW agent (before any ZeRO-3 wrap:
            # the wrapper forwards learner_step to this inner agent)
            self.agent.replay = self._replay_service
            self.partition_replay = {
                "axis": rax.name, "n_shards": rax.size,
                "capacity": flat_replay.capacity,
                "chunk": self._replay_service.chunk}
        # metrics reduce over the sim grid only: replay-group members
        # compute identical metrics by construction, and averaging
        # duplicates would change the float association vs the flat plan
        self._pmean_axes = tuple(
            a.name for a in plan.axes
            if not (a.role == "replay" and a.size > 1))
        self.mesh = None
        self._grad_tx = self._param_tx = None
        if plan.n_devices > 1:
            # validate_devices raises the clear device-count error
            # instead of silently slicing a too-short device list
            self.mesh = plan.build_mesh(jax.devices())
            self._grad_tx, self._param_tx = plan.compile_collectives()
        if self._sharded:
            self.agent.opt = zero_sharded_optimizer(
                self.agent.opt, shard.name, shard.size)
        if self._zero3:
            # wrap AFTER the opt swap: the wrapper's inner.init then
            # produces the chunk-shaped opt_state ZeRO-3 stores
            self.agent = ZeRO3Agent(self.agent, shard.name, shard.size)
        self._base_key = jax.random.PRNGKey(cfg.seed)
        self._step_cache = {}
        self.actor_shards = []   # actual env count per superstep dispatch
        # trajectory-queue depth the plan's sync hierarchy admits for
        # the decoupled actor-learner pipeline; 0 (lockstep) unless
        # cfg.pipeline asks for the split superstep
        self.pipeline_depth = plan.pipeline_depth if cfg.pipeline else 0

    @property
    def pipeline_capacity(self) -> Optional[int]:
        """Ring capacity of the trajectory queue (None when fused):
        steady state holds exactly `pipeline_depth` in-flight
        trajectories; depth 0 still needs the one lockstep slot."""
        return max(self.pipeline_depth, 1) if self.cfg.pipeline else None

    # ---- episode accounting (carried across iterations) --------------
    @staticmethod
    def _episode_stats(ep_run, ep_last, traj):
        """Exact per-episode returns from a (T, B) reward/done block.

        `ep_run` carries each env's within-episode reward sum across
        iteration boundaries, so `episode_return` is the mean return of
        episodes that *completed* this iteration — never a raw reward
        sum. With zero completions the last known value (NaN before the
        first episode ever finishes) is reported instead of a silently
        wrong number."""
        def acct(carry, xs):
            run, tot, cnt = carry
            r, d = xs
            run = run + r
            tot = tot + jnp.where(d, run, 0.0).sum()
            cnt = cnt + d.sum()
            run = jnp.where(d, 0.0, run)
            return (run, tot, cnt), None

        (ep_run, tot, cnt), _ = jax.lax.scan(
            acct, (ep_run, jnp.zeros(()), jnp.zeros((), jnp.int32)),
            (traj["reward"], traj["done"]))
        ep_ret = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), ep_last)
        return ep_run, ep_ret

    # ---- producer/consumer halves (shared by fused + pipelined) ------
    def _iter_key(self, it):
        """(k_roll, k_learn) for iteration `it` — one deterministic
        stream per iteration, independent of which program (fused tick,
        producer, consumer) derives it, so the pipelined split consumes
        randomness bitwise-identically to the fused scan."""
        key = jax.random.fold_in(self._base_key, it)
        if self.mesh is not None:
            # per-device RNG stream keyed by the FLAT device index of
            # the SIMULATION grid, so a (hosts, workers) nesting folds
            # the same stream ids as the flat plan and every member of
            # a replay group draws its data position's stream
            # (bitwise-parity invariants; sim_index == linear_index on
            # plans without an active replay axis)
            key = jax.random.fold_in(key, self.plan.sim_index())
        return jax.random.split(key)

    def _produce(self, state, env_state, it, delay=None):
        """Rollout-producer half: one trajectory for iteration `it`
        plus its bootstrap observation (the queue item — boot_obs must
        ride along because the consumer never sees the env state).
        `delay` defaults to the deterministic policy-lag floor: in
        pipelined mode the producer always acts with the newest params
        available, and any extra staleness is structural (the queue
        depth), not sampled."""
        delay = self.cfg.policy_lag if delay is None else delay
        k_roll, _ = self._iter_key(it)
        actor = self.agent.actor_policy(state, delay)
        traj, env_state = rollout(self.agent.policy, actor, self.env,
                                  k_roll, env_state, self.cfg.unroll)
        boot_obs = jax.vmap(self.env.obs)(env_state)
        return {"traj": traj, "boot": boot_obs}, env_state

    def _consume(self, state, ep_run, ep_last, item, it):
        """Learner-consumer half: one learner_step on a queue item plus
        the episode accounting (which must see trajectories in
        consumption order, so it lives on this side of the seam)."""
        _, k_learn = self._iter_key(it)
        state, metrics = self.agent.learner_step(
            state, item["traj"], item["boot"], k_learn,
            grad_tx=self._grad_tx, param_tx=self._param_tx)
        ep_run, ep_ret = self._episode_stats(ep_run, ep_last,
                                             item["traj"])
        metrics = dict(metrics, episode_return=ep_ret)
        if self.mesh is not None and self._pmean_axes:
            metrics = {k: jax.lax.pmean(v, self._pmean_axes)
                       for k, v in metrics.items()}
        return state, ep_run, ep_ret, metrics

    # ---- one training iteration (shared by fused/unfused paths) ------
    def _iteration(self, carry, xs):
        state, sim = carry
        it, delay = xs
        item, env_state = self._produce(state, sim["env"], it, delay)
        state, ep_run, ep_ret, metrics = self._consume(
            state, sim["ep_run"], sim["ep_last"], item, it)
        sim = {"env": env_state, "ep_run": ep_run, "ep_last": ep_ret}
        return (state, sim), metrics

    # ---- superstep: k fused iterations in one program ----------------
    def _superstep(self, k: int, donate: bool = None):
        """Jitted k-iteration program. With `donate` (cfg.donate by
        default) the `state`/`sim` argument buffers are donated to
        their same-shaped outputs, so the carried pytrees — DQN's
        capacity×transition replay store, the actor-param ring, env
        state — update in place instead of being copied once per
        dispatch (zero-copy superstep; measured in
        benchmarks/hotpath.py)."""
        donate = self.cfg.donate if donate is None else donate
        cache_key = (k, donate)
        if cache_key in self._step_cache:
            return self._step_cache[cache_key]
        donate_argnums = (0, 1) if donate else ()

        def body(state, sim, its, delays):
            (state, sim), metrics = jax.lax.scan(
                self._iteration, (state, sim), (its, delays))
            return state, sim, metrics

        if self.mesh is None:
            fn = jax.jit(body, donate_argnums=donate_argnums)
        else:
            from jax.experimental.shard_map import shard_map
            nd = len(self.plan.axes)

            def worker(state, sim, its, delays):
                # shard_map keeps one length-1 dim per mesh axis on the
                # sharded leaves — strip before the body, restore after
                state, sim, metrics = body(
                    strip_worker_dim(state, nd),
                    strip_worker_dim(sim, nd),
                    its, delays.reshape(delays.shape[0]))
                return (restore_worker_dim(state, nd),
                        restore_worker_dim(sim, nd), metrics)

            w = P(*self.plan.axis_names)
            fn = jax.jit(shard_map(
                worker, mesh=self.mesh,
                in_specs=(w, w, P(), P(None, *self.plan.axis_names)),
                out_specs=(w, w, P()), check_rep=False),
                donate_argnums=donate_argnums)
        self._step_cache[cache_key] = fn
        return fn

    # ---- pipelined superstep: decoupled producer/consumer ------------
    def _pipe_tick(self, state, sim, queue, it, delay):
        """One pipelined tick for consumer iteration `it`.

        depth 0: lockstep — push-then-pop through a one-slot queue is
        the identity on the item stream, so the round-trip is compiled
        away (the queue rides the carry untouched). This is not just an
        optimization: the buffer write would force XLA to materialize
        `traj` instead of fusing it into the consumer's reductions,
        drifting ~1 ulp from the fused program and breaking the depth-0
        bitwise guarantee (tests/test_pipeline.py pins it).

        depth d >= 1: pop FIRST (the popped item — produced d ticks ago
        — depends only on the carry-in queue, never on this tick's
        rollout), then produce iteration `it + d` and push. The two
        halves share only the carry-in `state`, so XLA schedules the
        rollout of iteration it+d concurrently with the learner update
        of iteration it; the tick's critical path is
        max(t_produce, t_consume) instead of their sum."""
        d = self.pipeline_depth
        if d == 0:
            item_c, env_state = self._produce(state, sim["env"], it,
                                              delay)
        else:
            queue, item_c, _ = queue_pop(queue)
            item_p, env_state = self._produce(state, sim["env"], it + d,
                                              delay)
            queue, _ = queue_push(queue, item_p)
        state, ep_run, ep_ret, metrics = self._consume(
            state, sim["ep_run"], sim["ep_last"], item_c, it)
        sim = {"env": env_state, "ep_run": ep_run, "ep_last": ep_ret}
        return state, sim, queue, metrics

    def _pipeline_superstep(self, k: int, donate: bool = None):
        """Jitted k-tick pipelined program (the consumer-side lowering;
        the queue rides the carry and is donated with state/sim).

        At depth >= 1 ticks are UNROLLED — a lax.scan body executes
        serially under the XLA schedulers, which would hide the
        producer/consumer independence `_pipe_tick` sets up. At depth 0
        there is nothing to overlap (lockstep by definition), so ticks
        run under lax.scan like the fused path: unrolling lets XLA fuse
        across tick boundaries and drift ~1 ulp from the scanned
        program, which would break the depth-0 bitwise guarantee."""
        donate = self.cfg.donate if donate is None else donate
        cache_key = ("pipe", k, donate)
        if cache_key in self._step_cache:
            return self._step_cache[cache_key]
        donate_argnums = (0, 1, 2) if donate else ()

        def body(state, sim, queue, its, delays):
            if self.pipeline_depth == 0:
                def tick(carry, xs):
                    state, sim, queue = carry
                    state, sim, queue, m = self._pipe_tick(
                        state, sim, queue, *xs)
                    return (state, sim, queue), m
                (state, sim, queue), metrics = jax.lax.scan(
                    tick, (state, sim, queue), (its, delays))
                return state, sim, queue, metrics
            per = []
            for j in range(k):
                state, sim, queue, m = self._pipe_tick(
                    state, sim, queue, its[j], delays[j])
                # fence the carry at tick boundaries: without it XLA
                # fuses across ticks and a k-tick program drifts ~1 ulp
                # from k dispatches of 1-tick programs (chunked fits
                # stop being bitwise one-shot fits — the fence restores
                # that for value-based learners; policy-gradient
                # learners with internal epoch scans keep ~1-ulp chunk
                # variance, pinned as allclose in tests). The fence adds
                # no serialization the dataflow didn't already have —
                # produce(t+1) reads consume(t)'s state — so the
                # within-tick produce/consume independence survives.
                state, sim, queue = jax.lax.optimization_barrier(
                    (state, sim, queue))
                per.append(m)
            metrics = {key: jnp.stack([m[key] for m in per])
                       for key in per[0]}
            return state, sim, queue, metrics

        if self.mesh is None:
            fn = jax.jit(body, donate_argnums=donate_argnums)
        else:
            from jax.experimental.shard_map import shard_map
            nd = len(self.plan.axes)

            def worker(state, sim, queue, its, delays):
                state, sim, queue, metrics = body(
                    strip_worker_dim(state, nd),
                    strip_worker_dim(sim, nd),
                    strip_worker_dim(queue, nd), its,
                    delays.reshape(delays.shape[0]))
                return (restore_worker_dim(state, nd),
                        restore_worker_dim(sim, nd),
                        restore_worker_dim(queue, nd), metrics)

            w = P(*self.plan.axis_names)
            fn = jax.jit(shard_map(
                worker, mesh=self.mesh,
                in_specs=(w, w, w, P(), P(None, *self.plan.axis_names)),
                out_specs=(w, w, w, P()), check_rep=False),
                donate_argnums=donate_argnums)
        self._step_cache[cache_key] = fn
        return fn

    def _producer_program(self, k: int):
        """Jitted k-iteration rollout-only program (the producer-side
        lowering): fills the queue with trajectories for iterations
        its[0..k-1] before the first pipelined tick runs. `state` is
        read-only here — the first tick still needs its buffers, so only
        sim/queue are donated."""
        cache_key = ("fill", k)
        if cache_key in self._step_cache:
            return self._step_cache[cache_key]
        donate_argnums = (1, 2) if self.cfg.donate else ()

        def body(state, sim, queue, its, delays):
            env_state = sim["env"]
            for j in range(k):
                item, env_state = self._produce(state, env_state,
                                                its[j], delays[j])
                queue, _ = queue_push(queue, item)
            sim = {"env": env_state, "ep_run": sim["ep_run"],
                   "ep_last": sim["ep_last"]}
            return sim, queue

        if self.mesh is None:
            fn = jax.jit(body, donate_argnums=donate_argnums)
        else:
            from jax.experimental.shard_map import shard_map
            nd = len(self.plan.axes)

            def worker(state, sim, queue, its, delays):
                sim, queue = body(
                    strip_worker_dim(state, nd),
                    strip_worker_dim(sim, nd),
                    strip_worker_dim(queue, nd), its,
                    delays.reshape(delays.shape[0]))
                return (restore_worker_dim(sim, nd),
                        restore_worker_dim(queue, nd))

            w = P(*self.plan.axis_names)
            fn = jax.jit(shard_map(
                worker, mesh=self.mesh,
                in_specs=(w, w, w, P(), P(None, *self.plan.axis_names)),
                out_specs=(w, w), check_rep=False),
                donate_argnums=donate_argnums)
        self._step_cache[cache_key] = fn
        return fn

    def _consumer_program(self, k: int):
        """Jitted k-iteration learner-only program (the consumer-side
        lowering): pops one queued trajectory per iteration and runs
        learner_step + episode accounting on it. `fit` never calls this
        — the pipelined tick fuses both halves — but it is the serial
        half of the decoupled baseline benchmarks/pipeline_overlap.py
        measures the pipelined program against, and the natural drain
        primitive for a future multi-host split (ROADMAP)."""
        cache_key = ("drain", k)
        if cache_key in self._step_cache:
            return self._step_cache[cache_key]
        donate_argnums = (0, 1, 2) if self.cfg.donate else ()

        def body(state, sim, queue, its):
            ep_run, ep_last = sim["ep_run"], sim["ep_last"]
            per = []
            for j in range(k):
                queue, item, _ = queue_pop(queue)
                state, ep_run, ep_ret, m = self._consume(
                    state, ep_run, ep_last, item, its[j])
                ep_last = ep_ret
                per.append(m)
            metrics = {key: jnp.stack([m[key] for m in per])
                       for key in per[0]}
            sim = {"env": sim["env"], "ep_run": ep_run,
                   "ep_last": ep_last}
            return state, sim, queue, metrics

        if self.mesh is None:
            fn = jax.jit(body, donate_argnums=donate_argnums)
        else:
            from jax.experimental.shard_map import shard_map
            nd = len(self.plan.axes)

            def worker(state, sim, queue, its):
                state, sim, queue, metrics = body(
                    strip_worker_dim(state, nd),
                    strip_worker_dim(sim, nd),
                    strip_worker_dim(queue, nd), its)
                return (restore_worker_dim(state, nd),
                        restore_worker_dim(sim, nd),
                        restore_worker_dim(queue, nd), metrics)

            w = P(*self.plan.axis_names)
            fn = jax.jit(shard_map(
                worker, mesh=self.mesh,
                in_specs=(w, w, w, P()),
                out_specs=(w, w, w, P()), check_rep=False),
                donate_argnums=donate_argnums)
        self._step_cache[cache_key] = fn
        return fn

    def _init_queue(self, state, sim):
        """Empty trajectory queue sized for `pipeline_capacity` items.

        Item shapes come from a shape-only trace (eval_shape) of the
        producer on PER-DEVICE inputs — a dedicated closure with a dummy
        key, because `_iter_key` folds in `plan.linear_index()`
        (axis_index), which only exists inside shard_map. Under a mesh
        the queue leaves get the same leading mesh dims as state/sim so
        one `P(*axis_names)` spec shards every carry argument alike."""
        cap = self.pipeline_capacity
        nd = 0 if self.mesh is None else len(self.plan.axes)
        sds = lambda t: jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[nd:], a.dtype), t)

        def one_item(state, env_state):
            actor = self.agent.actor_policy(state, self.cfg.policy_lag)
            traj, env_state = rollout(
                self.agent.policy, actor, self.env,
                jax.random.PRNGKey(0), env_state, self.cfg.unroll)
            return {"traj": traj,
                    "boot": jax.vmap(self.env.obs)(env_state)}

        item = jax.eval_shape(one_item, sds(state), sds(sim["env"]))
        if self.mesh is None:
            return queue_init(item, cap)
        lead = self.plan.mesh_shape
        buf = jax.tree_util.tree_map(
            lambda s: jnp.zeros(lead + (cap,) + tuple(s.shape), s.dtype),
            item)
        return {"buf": buf, "head": jnp.zeros(lead, jnp.int32),
                "tail": jnp.zeros(lead, jnp.int32)}

    # ---- state/schedule construction ---------------------------------
    def _shard_sim(self, sim):
        """Reshape a host-layout sim carry (flat env batch) into the
        plan's mesh layout: one leading dim per mesh axis, row-major, so
        device (i0, i1, ...) owns the same contiguous env slice its flat
        linear index would."""
        if self.mesh is None:
            return sim
        shape = self.plan.mesh_shape
        sshape = self.plan.sim_shape   # active replay axis -> 1
        per = sim["ep_run"].shape[0] // self.plan.sim_devices
        # reshape over the sim grid, then broadcast across the replay
        # axis: replay-group members REPLICATE their data position's
        # envs (identity when the sim grid is the whole mesh)
        lay = lambda a: jnp.broadcast_to(
            a.reshape(sshape + (per,) + a.shape[1:]),
            shape + (per,) + a.shape[1:])
        return {"env": jax.tree_util.tree_map(lay, sim["env"]),
                "ep_run": lay(sim["ep_run"]),
                "ep_last": jnp.broadcast_to(sim["ep_last"], shape)}

    def _init_all(self):
        cfg = self.cfg
        k_init, k_env, k_delay = jax.random.split(self._base_key, 3)
        state = self.agent.init(k_init)
        shard = self.plan.shard_axis
        if self._zero3:
            # the wrapper's init already ran flatten_and_pad PER ENTRY
            # (one entry per transformer block + remainder when the
            # agent yields a partition list; a single entry otherwise)
            # and caches the geometry + unravels on itself
            self._part_unravels = list(self.agent._unravels)
            self._part_unravel = self._part_unravels[0]
            self.partition = {
                "axis": shard.name, "n_shards": shard.size,
                "size": self.agent._size, "padded": self.agent._padded,
                "chunk": self.agent._chunk,
                "sizes": list(self.agent._sizes),
                "chunks": list(self.agent._chunks),
                "entries": self.agent.n_entries,
                "listwise": self.agent._listwise}
        elif self._sharded:
            # record the flatten-and-pad partition of the optimizer
            # target (agent.partition_spec) for reporting, benchmarks
            # and the end-of-fit opt_state reassembly; padded size is
            # divisible by the shard size by construction
            vec, size, unravel = flatten_and_pad(
                self.agent.partition_spec(state), shard.size)
            self._part_unravel = unravel
            self._part_unravels = [unravel]
            self.partition = {
                "axis": shard.name, "n_shards": shard.size,
                "size": int(size), "padded": int(vec.size),
                "chunk": int(vec.size // shard.size),
                "listwise": False}
        # simulation-side carry: batched env state + episode accounting
        # (ep_last starts NaN: no episode has finished yet)
        sim = {"env": self.env.reset_batch(k_env, cfg.n_envs),
               "ep_run": jnp.zeros((cfg.n_envs,)),
               "ep_last": jnp.full((), jnp.nan)}
        delays = (self.plan.make_delay_schedule(cfg.iters, k_delay)
                  + cfg.policy_lag)
        if self.mesh is not None:
            rstate = None
            if self._replay:
                # pull the flat host replay out of the state (None is an
                # empty pytree — it rides through either layout path
                # untouched), shard it 1/N and spread the shards along
                # the replay mesh axis while everything else replicates
                rstate = self._replay_service.shard_state(
                    state.extra["replay"])
                state = self._swap_replay(state, None)
            state = (self._lay_out_zero3(state) if self._zero3
                     else replicate_for(self.mesh, self.plan.axis_names,
                                        state))
            if rstate is not None:
                state = self._swap_replay(state,
                                          self._spread_replay(rstate))
            sim = self._shard_sim(sim)
        else:
            delays = delays.reshape(cfg.iters)
        return state, sim, delays

    @staticmethod
    def _swap_replay(state, rstate):
        extra = dict(state.extra)
        extra["replay"] = rstate
        return agent_api.TrainState(state.params, state.opt_state,
                                    extra, state.ring, state.steps)

    def _spread_replay(self, tree):
        """Mesh layout for host sharded replay leaves (leading
        (n_shards,) dim from `shard_state`): distribute that dim along
        the replay mesh axis — the device at replay index r owns chunk
        r — and replicate over every other axis (the `_lay_out_zero3`
        spread pattern)."""
        names = self.plan.axis_names
        shape = self.plan.mesh_shape
        k = names.index(self.plan.replay_axis.name)

        def spread(a):
            lead = [1] * len(names)
            lead[k] = a.shape[0]
            a = a.reshape(tuple(lead) + a.shape[1:])
            return jnp.broadcast_to(a, shape + a.shape[len(names):])

        return jax.tree_util.tree_map(spread, tree)

    def _lay_out_zero3(self, state):
        """Mesh layout for a HOST-layout ZeRO-3 TrainState: chunked
        leaves (params["zero3"] entries (n_shards, chunk_e); ring
        entries (n_shards, ring_size, chunk_e)) distribute their
        leading dim along the shard mesh axis — device at shard index i
        owns chunk i — while every other leaf replicates like
        `replicate_for`."""
        names = self.plan.axis_names
        shape = self.plan.mesh_shape
        k = names.index(self.partition["axis"])

        def spread(a):
            lead = [1] * len(names)
            lead[k] = a.shape[0]
            a = a.reshape(tuple(lead) + a.shape[1:])
            return jnp.broadcast_to(a, shape + a.shape[len(names):])

        repl = lambda t: jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p, shape + p.shape), t)
        return agent_api.TrainState(
            {"zero3": jax.tree_util.tree_map(
                spread, state.params["zero3"]),
             "rest": repl(state.params["rest"])},
            repl(state.opt_state), repl(state.extra),
            jax.tree_util.tree_map(spread, state.ring),
            repl(state.steps))

    # ---- elastic actor shards (plan.actors) ---------------------------
    def _reshard_envs(self, sim, n_total, key):
        """Grow/shrink the env-shard count between supersteps. Shrinking
        drops the trailing shards (their in-flight episode accumulators
        with them); growing resets fresh envs into the new slots. The
        agents never see this — they only consume `traj`."""
        lead = 0 if self.mesh is None else len(self.plan.axes)
        nd = self.plan.sim_devices
        per_new = n_total // nd
        per_cur = sim["ep_run"].shape[lead]
        if per_new == per_cur:
            return sim
        keep = (slice(None),) * lead
        if per_new < per_cur:
            env = jax.tree_util.tree_map(
                lambda a: a[keep + (slice(0, per_new),)], sim["env"])
            ep_run = sim["ep_run"][keep + (slice(0, per_new),)]
        else:
            fresh = {"env": self.env.reset_batch(
                         key, (per_new - per_cur) * nd),
                     "ep_run": jnp.zeros(((per_new - per_cur) * nd,)),
                     "ep_last": sim["ep_last"]}
            fresh = self._shard_sim(fresh)
            env = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], axis=lead),
                sim["env"], fresh["env"])
            ep_run = jnp.concatenate([sim["ep_run"], fresh["ep_run"]],
                                     axis=lead)
        return {"env": env, "ep_run": ep_run, "ep_last": sim["ep_last"]}

    def lower(self, k: int = None, donate: bool = None):
        """Lower (without running) one superstep — lets benchmarks
        inspect the collective schedule (HLO) per plan and the donation
        plan (compile().memory_analysis())."""
        k = self.cfg.superstep if k is None else k
        state, sim, delays = self._init_all()
        its = jnp.arange(k, dtype=jnp.int32)
        return self._superstep(k, donate).lower(state, sim, its,
                                                delays[:k])

    def lower_pipelined(self, k: int = None, donate: bool = None):
        """Lower (without running) one pipelined superstep — the
        consumer-side program with the trajectory queue in its carry."""
        k = self.cfg.superstep if k is None else k
        state, sim, delays = self._init_all()
        queue = self._init_queue(state, sim)
        its = jnp.arange(k, dtype=jnp.int32)
        return self._pipeline_superstep(k, donate).lower(
            state, sim, queue, its,
            jnp.full_like(delays[:k], self.cfg.policy_lag))

    # ---- the driver --------------------------------------------------
    def fit(self, fused: bool = True):
        """Train for cfg.iters iterations. Returns (TrainState, history);
        with a multi-device plan the returned state is device 0's
        replica."""
        cfg = self.cfg
        state, sim, delays = self._init_all()
        queue = None
        if cfg.pipeline:
            # the pipelined producer acts at the constant policy_lag
            # floor — structural queue staleness replaces the sampled
            # delay schedule — but the delay still enters the program
            # as an INPUT so the ring read lowers to the same dynamic
            # slice as the fused path (depth-0 bitwise guarantee)
            delays = jnp.full_like(delays, cfg.policy_lag)
            # prologue: fill the queue so the producer starts `depth`
            # iterations ahead of the consumer. The queue then PERSISTS
            # across superstep dispatches (no drain at chunk
            # boundaries), so chunked fits equal one-shot fits. The
            # producer over-runs by `depth` wasted rollouts at the tail
            # — the price of a uniform tick program.
            queue = self._init_queue(state, sim)
            if self.pipeline_depth:
                fill = self._producer_program(self.pipeline_depth)
                its0 = jnp.arange(self.pipeline_depth, dtype=jnp.int32)
                sim, queue = fill(state, sim, queue, its0,
                                  delays[:self.pipeline_depth])
        K = cfg.superstep if fused else 1
        history = []
        start = 0
        self.actor_shards = []
        while start < cfg.iters:
            k = min(K, cfg.iters - start)
            # the actors= schedule is indexed by cfg.superstep-iteration
            # window (not dispatch count), so fused and unfused runs
            # reshard at the same iteration boundaries and stay
            # numerically equivalent
            s_idx = start // cfg.superstep
            n_envs = self.plan.actor_schedule(s_idx, cfg.n_envs)
            # reshard key offset far above any iteration index so elastic
            # env resets never alias an iteration's rollout stream
            sim = self._reshard_envs(
                sim, n_envs,
                jax.random.fold_in(self._base_key, (1 << 20) + s_idx))
            self.actor_shards.append(n_envs)
            its = jnp.arange(start, start + k, dtype=jnp.int32)
            if cfg.pipeline:
                step = self._pipeline_superstep(k)
                state, sim, queue, metrics = step(
                    state, sim, queue, its, delays[start:start + k])
            else:
                step = self._superstep(k)
                state, sim, metrics = step(state, sim, its,
                                           delays[start:start + k])
            metrics = jax.device_get(metrics)  # ONE host sync per chunk
            for j in range(k):
                it = start + j
                if it % cfg.log_every == 0 or it == cfg.iters - 1:
                    history.append({"iter": it, **{
                        name: round(float(v[j]), 4)
                        for name, v in sorted(metrics.items())}})
            start += k
        if self.mesh is not None:
            first = (0,) * len(self.plan.axes)
            take0 = lambda t: jax.tree_util.tree_map(
                lambda a: a[first], t)
            rfull = None
            if self._replay:
                # reassemble the logical buffer from every replay shard
                # (row 0 of the other axes) BEFORE the generic device-0
                # extraction, which would keep only chunk 0 — then
                # splice the flat host form back in: fit()'s result and
                # checkpoints stay plan-independent
                nd = len(self.plan.axes)
                k = self.plan.axis_names.index(
                    self.plan.replay_axis.name)
                idx = tuple(slice(None) if i == k else 0
                            for i in range(nd))
                rfull = self._replay_service.unshard_state(
                    jax.tree_util.tree_map(lambda a: a[idx],
                                           state.extra["replay"]))
                state = self._swap_replay(state, None)
            if self._zero3:
                state = self._unshard_zero3(state, take0)
            elif self.partition is not None:
                # checkpoint-shaped result: reassemble the ZeRO shards
                # into the replicated-form opt_state before dropping
                # the mesh dims (device 0 for everything else)
                state = agent_api.TrainState(
                    take0(state.params),
                    self._unshard_opt_state(state.opt_state),
                    take0(state.extra), take0(state.ring),
                    take0(state.steps))
            else:
                state = take0(state)
            if rfull is not None:
                state = self._swap_replay(state, rfull)
        return state, history

    def _unshard_zero3(self, state, take0):
        """Reassemble a mesh-layout ZeRO-3 TrainState into the inner
        agent's replicated tree form (checkpoint shape): each partition
        entry's param and ring chunks are gathered along the shard axis
        (row 0 of every data axis), trimmed of padding and unraveled,
        then merged (restacking the per-block entries when the agent is
        layer-wise); opt_state goes through the ZeRO-2/per-entry
        reassembly; rest/extra/steps come from device 0."""
        p = self.partition
        nd = len(self.plan.axes)
        k = self.plan.axis_names.index(p["axis"])
        idx = tuple(slice(None) if i == k else 0 for i in range(nd))
        merge = (lambda es: self.agent.merge_partition_list(
            es, materialize=True)) if p["listwise"] else (
            lambda es: es[0])
        E = p["entries"]
        sub = merge([self._part_unravels[e](
            state.params["zero3"][e][idx].reshape(-1)[:p["sizes"][e]])
            for e in range(E)])
        params = self.agent.replace_partition(
            take0(state.params["rest"]), sub)
        slots = []
        for d in range(self.agent.ring_size):
            # ring entry e at idx: (n_shards, ring_size, chunk_e)
            slots.append(merge([self._part_unravels[e](
                state.ring[e][idx][:, d, :].reshape(-1)[:p["sizes"][e]])
                for e in range(E)]))
        ring = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *slots)
        return agent_api.TrainState(
            params, self._unshard_opt_state(state.opt_state),
            take0(state.extra), ring, take0(state.steps))

    def _unshard_opt_state(self, opt_state):
        """Reassemble a ZeRO-sharded opt_state (leaves carrying one
        leading mesh dim per axis) into the replicated tree form:
        chunk-shaped leaves are gathered along the shard axis (row 0 of
        every data axis), concatenated in shard order, trimmed of the
        flatten-and-pad padding and unraveled back into the partition
        target's pytree shape; other leaves (e.g. the step counter)
        come from device 0. A shard axis of size 1 therefore returns
        bitwise the replicated-trainer opt_state — checkpoints keep
        their shape across plans.

        Layer-wise ZeRO-3 opt_states are a LIST over partition entries
        of inner states (one chunk per entry): congruent leaf positions
        are gathered per entry, unraveled with that entry's unravel and
        merged back into the partition-shaped tree (scalars like the
        step counter are identical across entries — entry 0 is
        taken)."""
        p = self.partition
        nd = len(self.plan.axes)
        k = self.plan.axis_names.index(p["axis"])
        idx = tuple(slice(None) if i == k else 0 for i in range(nd))

        if p.get("listwise"):
            E = p["entries"]
            flats = [jax.tree_util.tree_flatten(opt_state[e])
                     for e in range(E)]
            leaves0, treedef = flats[0]
            out = []
            for i in range(len(leaves0)):
                per = [flats[e][0][i] for e in range(E)]
                if all(per[e].shape[nd:] == (p["chunks"][e],)
                       for e in range(E)):
                    out.append(self.agent.merge_partition_list(
                        [self._part_unravels[e](
                            per[e][idx].reshape(-1)[:p["sizes"][e]])
                         for e in range(E)], materialize=True))
                else:
                    out.append(per[0][(0,) * nd])
            return jax.tree_util.tree_unflatten(treedef, out)

        def leaf(a):
            if a.shape[nd:] == (p["chunk"],):
                return self._part_unravel(
                    a[idx].reshape(-1)[:p["size"]])
            return a[(0,) * nd]

        return jax.tree_util.tree_map(leaf, opt_state)
