"""Unified Trainer: one fused, topology- and sync-aware training driver.

Composes the survey's three acceleration axes over any registered Agent
(repro.core.agent) instead of one hand-written driver per algorithm:

  * batch simulation (§4.2): the shared rollout engine fuses env
    dynamics + policy inference into the training program;
  * system topology (§3, Fig. 3): with `n_workers > 1` the whole
    iteration runs per-worker inside a `shard_map` over a `workers`
    mesh axis, gradients routed through `topology.exchange_grads`
    (ps/allreduce) or params mixed by `topology.gossip_mix` (gossip);
  * synchronization (§6, Fig. 6): bsp/asp/ssp are rendered as a
    deterministic policy-lag schedule (`sync.make_delays`) indexing each
    agent's actor-param ring — workers act with stale params, exactly
    the staleness the mechanisms differ by.

`fit(fused=True)` scans `superstep` iterations (rollout -> learner_step
-> lag-ring rotate) inside ONE jitted `lax.scan`: the Python loop
dispatches iters/K programs and reads metrics back once per superstep
instead of blocking on `float(...)` every iteration.  `fit(fused=False)`
runs the identical iteration body one step at a time — numerically
equivalent (tests/test_trainer.py) but host-bound; the speedup is
measured in benchmarks/fused_superstep.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import agent as agent_api
from repro.core.rollout import rollout
from repro.core.sync import MECHANISMS, SyncConfig, make_delays
from repro.core.topology import (TOPOLOGIES, exchange_grads, gossip_mix,
                                 replicate_for, restore_worker_dim,
                                 strip_worker_dim)

AXIS = "workers"


@dataclasses.dataclass
class TrainerConfig:
    algo: str = "impala"
    iters: int = 60
    superstep: int = 10        # K iterations fused per jitted dispatch
    n_envs: int = 32           # total envs (split across workers)
    unroll: int = 32           # rollout length T per iteration
    n_workers: int = 1
    topology: str = "allreduce"   # §3: ps | allreduce | gossip
    sync: str = "bsp"             # §6: bsp | asp | ssp
    policy_lag: int = 0        # deterministic actor-param lag floor
    max_delay: int = 4         # asp worst-case extra staleness
    staleness_bound: int = 1   # ssp bound on extra staleness
    seed: int = 0
    log_every: int = 10
    donate: bool = True        # zero-copy supersteps: donate state/sim
    algo_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ring_size(self) -> int:
        """Actor-param history depth the sync mechanism can reach into."""
        extra = {"bsp": 0, "asp": self.max_delay,
                 "ssp": min(self.max_delay, self.staleness_bound)}
        return self.policy_lag + extra[self.sync] + 1


class Trainer:
    """Drives any registered Agent; see module docstring."""

    def __init__(self, env, cfg: TrainerConfig):
        if cfg.topology not in TOPOLOGIES:
            raise ValueError(f"topology {cfg.topology!r} not in "
                             f"{TOPOLOGIES}")
        if cfg.sync not in MECHANISMS:
            raise ValueError(f"sync {cfg.sync!r} not in {MECHANISMS}")
        if cfg.n_envs % cfg.n_workers:
            raise ValueError(f"n_envs={cfg.n_envs} must divide evenly "
                             f"across n_workers={cfg.n_workers}")
        self.env = env
        self.cfg = cfg
        self.agent = agent_api.make(cfg.algo, env=env,
                                    ring_size=cfg.ring_size,
                                    total_iters=cfg.iters,
                                    **cfg.algo_kwargs)
        self.mesh = None
        if cfg.n_workers > 1:
            devs = jax.devices()
            if len(devs) < cfg.n_workers:
                raise RuntimeError(
                    f"n_workers={cfg.n_workers} needs {cfg.n_workers} "
                    f"devices but only {len(devs)} are visible; set "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{cfg.n_workers} before importing jax (the "
                    f"rl_train CLI does this automatically)")
            self.mesh = Mesh(np.array(devs[:cfg.n_workers]), (AXIS,))
            self._grad_tx = lambda g: exchange_grads(g, AXIS, cfg.topology)
            self._param_tx = ((lambda p: gossip_mix(p, AXIS))
                              if cfg.topology == "gossip" else None)
        else:
            self._grad_tx = self._param_tx = None
        self._base_key = jax.random.PRNGKey(cfg.seed)
        self._step_cache = {}

    # ---- episode accounting (carried across iterations) --------------
    @staticmethod
    def _episode_stats(ep_run, ep_last, traj):
        """Exact per-episode returns from a (T, B) reward/done block.

        `ep_run` carries each env's within-episode reward sum across
        iteration boundaries, so `episode_return` is the mean return of
        episodes that *completed* this iteration — never a raw reward
        sum. With zero completions the last known value (NaN before the
        first episode ever finishes) is reported instead of a silently
        wrong number."""
        def acct(carry, xs):
            run, tot, cnt = carry
            r, d = xs
            run = run + r
            tot = tot + jnp.where(d, run, 0.0).sum()
            cnt = cnt + d.sum()
            run = jnp.where(d, 0.0, run)
            return (run, tot, cnt), None

        (ep_run, tot, cnt), _ = jax.lax.scan(
            acct, (ep_run, jnp.zeros(()), jnp.zeros((), jnp.int32)),
            (traj["reward"], traj["done"]))
        ep_ret = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1), ep_last)
        return ep_run, ep_ret

    # ---- one training iteration (shared by fused/unfused paths) ------
    def _iteration(self, carry, xs):
        state, sim = carry
        it, delay = xs
        key = jax.random.fold_in(self._base_key, it)
        if self.mesh is not None:
            key = jax.random.fold_in(key, jax.lax.axis_index(AXIS))
        k_roll, k_learn = jax.random.split(key)
        actor = self.agent.actor_policy(state, delay)
        traj, env_state = rollout(self.agent.policy, actor, self.env,
                                  k_roll, sim["env"], self.cfg.unroll)
        boot_obs = jax.vmap(self.env.obs)(env_state)
        state, metrics = self.agent.learner_step(
            state, traj, boot_obs, k_learn,
            grad_tx=self._grad_tx, param_tx=self._param_tx)
        ep_run, ep_ret = self._episode_stats(sim["ep_run"],
                                             sim["ep_last"], traj)
        metrics = dict(metrics, episode_return=ep_ret)
        if self.mesh is not None:
            metrics = {k: jax.lax.pmean(v, AXIS)
                       for k, v in metrics.items()}
        sim = {"env": env_state, "ep_run": ep_run, "ep_last": ep_ret}
        return (state, sim), metrics

    # ---- superstep: k fused iterations in one program ----------------
    def _superstep(self, k: int, donate: bool = None):
        """Jitted k-iteration program. With `donate` (cfg.donate by
        default) the `state`/`sim` argument buffers are donated to
        their same-shaped outputs, so the carried pytrees — DQN's
        capacity×transition replay store, the actor-param ring, env
        state — update in place instead of being copied once per
        dispatch (zero-copy superstep; measured in
        benchmarks/hotpath.py)."""
        donate = self.cfg.donate if donate is None else donate
        cache_key = (k, donate)
        if cache_key in self._step_cache:
            return self._step_cache[cache_key]
        donate_argnums = (0, 1) if donate else ()

        def body(state, sim, its, delays):
            (state, sim), metrics = jax.lax.scan(
                self._iteration, (state, sim), (its, delays))
            return state, sim, metrics

        if self.mesh is None:
            fn = jax.jit(body, donate_argnums=donate_argnums)
        else:
            from jax.experimental.shard_map import shard_map

            def worker(state, sim, its, delays):
                # shard_map keeps the (length-1) worker dim — strip/restore
                state, sim, metrics = body(
                    strip_worker_dim(state), strip_worker_dim(sim),
                    its, delays[:, 0])
                return (restore_worker_dim(state),
                        restore_worker_dim(sim), metrics)

            w = P(AXIS)
            fn = jax.jit(shard_map(
                worker, mesh=self.mesh,
                in_specs=(w, w, P(), P(None, AXIS)),
                out_specs=(w, w, P()), check_rep=False),
                donate_argnums=donate_argnums)
        self._step_cache[cache_key] = fn
        return fn

    # ---- state/schedule construction ---------------------------------
    def _init_all(self):
        cfg = self.cfg
        k_init, k_env, k_delay = jax.random.split(self._base_key, 3)
        state = self.agent.init(k_init)
        # simulation-side carry: batched env state + episode accounting
        # (ep_last starts NaN: no episode has finished yet)
        sim = {"env": self.env.reset_batch(k_env, cfg.n_envs),
               "ep_run": jnp.zeros((cfg.n_envs,)),
               "ep_last": jnp.full((), jnp.nan)}
        delays = make_delays(
            SyncConfig(cfg.sync, cfg.n_workers, cfg.max_delay,
                       cfg.staleness_bound),
            cfg.iters, k_delay) + cfg.policy_lag
        if self.mesh is not None:
            W = cfg.n_workers
            state = replicate_for(self.mesh, AXIS, state)
            sim = {"env": jax.tree_util.tree_map(
                       lambda a: a.reshape((W, a.shape[0] // W)
                                           + a.shape[1:]), sim["env"]),
                   "ep_run": sim["ep_run"].reshape(W, -1),
                   "ep_last": jnp.broadcast_to(sim["ep_last"], (W,))}
        else:
            delays = delays[:, 0]
        return state, sim, delays

    def lower(self, k: int = None, donate: bool = None):
        """Lower (without running) one superstep — lets benchmarks
        inspect the collective schedule (HLO) per topology and the
        donation plan (compile().memory_analysis())."""
        k = self.cfg.superstep if k is None else k
        state, sim, delays = self._init_all()
        its = jnp.arange(k, dtype=jnp.int32)
        return self._superstep(k, donate).lower(state, sim, its,
                                                delays[:k])

    # ---- the driver --------------------------------------------------
    def fit(self, fused: bool = True):
        """Train for cfg.iters iterations. Returns (TrainState, history);
        with n_workers > 1 the returned state is worker 0's replica."""
        cfg = self.cfg
        state, sim, delays = self._init_all()
        K = cfg.superstep if fused else 1
        history = []
        start = 0
        while start < cfg.iters:
            k = min(K, cfg.iters - start)
            step = self._superstep(k)
            its = jnp.arange(start, start + k, dtype=jnp.int32)
            state, sim, metrics = step(state, sim, its,
                                       delays[start:start + k])
            metrics = jax.device_get(metrics)  # ONE host sync per chunk
            for j in range(k):
                it = start + j
                if it % cfg.log_every == 0 or it == cfg.iters - 1:
                    history.append({"iter": it, **{
                        name: round(float(v[j]), 4)
                        for name, v in sorted(metrics.items())}})
            start += k
        if self.mesh is not None:
            state = jax.tree_util.tree_map(lambda a: a[0], state)
        return state, history
