"""Sharded replay service — distributed replay memory as a DistPlan
axis role (survey §3: Gorila's Replay Memory component; the Ape-X /
SRL line puts replay on its own sharded service so capacity scales
with the cluster).

`ShardedPrioritizedReplay` renders that service as collectives over a
``replay``-role mesh axis: the replay group holds ONE logical
`PrioritizedReplay` of global `capacity`, each member owning the
contiguous slice ``[r*chunk, (r+1)*chunk)`` (chunk = capacity/n_shards)
of its store and priority vector — per-device replay bytes drop to
~1/n_shards (BENCH_replay.json). Members replicate the data-position
rollout/learner compute; only replay STORAGE is sharded.

The same `add_batch` / `sample` / `update_priorities` interface as
`PrioritizedReplay`, draw-for-draw and bitwise equivalent to the
single-buffer fused path given the same Gumbel draws:

  insert      every member computes the same global ring indices
              (`_ring_fit` on the replicated ptr); each scatters only
              the rows that land in its slice (out-of-slice writes are
              dropped via an OOB sentinel index). The Ape-X max-priority
              default reduces the global max with `pmax` — max is
              association-free, so sharding changes nothing bitwise.
  sample      every member draws the same global (capacity,) Gumbel
              vector from the shared key and slices its chunk; the
              PR 3 fused Gumbel-top-k kernel seam (`shard_gumbel_topk`)
              ranks the local top-k candidates, an `all_gather` merges
              them shard-major, and one top-n over the (n_shards*k,)
              candidates picks the batch. top_k is stable (ties break
              toward the lower input position) and shard-major merge
              preserves global index order among candidates, so the
              selected index sequence is bitwise one top-n over the
              flat score vector. IS weights are normalized against the
              GLOBAL priority mass: the (capacity,) priorities are
              all-gathered (zero3-style gather-per-use — transient, not
              persistent state) and fed through the ref's weight
              expressions verbatim (`prioritized_weights_ref`). Batch
              rows are assembled with a masked `psum` — each row is
              owned by exactly one shard, and x + 0 is exact.
  write-back  priority updates scatter through the same owner routing
              as insert.

Layout: per-member in-graph state keeps the flat buffer's dict keys
({"store", "prio", "ptr", "size"}) with store/prio chunk-sized and
ptr/size replicated scalars, so `DQNAgent.learner_step`'s warm-gating
(`rstate["prio"]`) works unchanged. `shard_state` / `unshard_state`
convert between the flat host form agents init/checkpoint (plan-
independent) and the host sharded layout the Trainer lays out along
the replay mesh axis (leading (n_shards,) dim on every leaf).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.replay import _ring_fit
from repro.core.replay_sample import shard_gumbel_topk
from repro.core.topology import all_gather_shards, psum_select
from repro.kernels.replay_sample.ref import prioritized_weights_ref


@dataclasses.dataclass
class ShardedPrioritizedReplay:
    """One logical prioritized buffer of `capacity` slots sharded
    1/n_shards per member over mesh axis `axis`. Methods run inside
    shard_map/vmap with `axis` in scope; state is the LOCAL member
    state (chunk-sized store/prio, replicated ptr/size scalars)."""
    capacity: int          # GLOBAL capacity (sum over the axis)
    axis: str              # replay-role mesh axis name
    n_shards: int
    alpha: float = 0.6
    beta: float = 0.4
    eps: float = 1e-6
    fused: bool = True     # Pallas kernel for the per-shard top-k

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"replay axis {self.axis!r}: n_shards "
                             f"{self.n_shards} < 1")
        if self.capacity % self.n_shards:
            raise ValueError(
                f"replay axis {self.axis!r}: replay capacity "
                f"{self.capacity} is not divisible by the axis size "
                f"{self.n_shards} — each member owns a contiguous "
                f"1/{self.n_shards} slice of the logical buffer; pick "
                f"a capacity that is a multiple of the axis size")

    @property
    def chunk(self) -> int:
        return self.capacity // self.n_shards

    # ---- owner routing ------------------------------------------------
    def _local(self, idx):
        """Global slot indices -> (local indices with an OOB sentinel
        for rows another shard owns, ownership mask). `.at[...].set(
        mode="drop")` then discards exactly the foreign rows."""
        r = jax.lax.axis_index(self.axis)
        local = idx - r * self.chunk
        own = (local >= 0) & (local < self.chunk)
        return jnp.where(own, local, self.chunk), own

    # ---- PrioritizedReplay interface ---------------------------------
    def init(self, example: Any):
        """LOCAL member state (the Trainer instead shards the flat
        buffer's host init via `shard_state` — this exists for direct
        vmap/shard_map use and tests)."""
        store = jax.tree_util.tree_map(
            lambda a: jnp.zeros((self.chunk,) + jnp.shape(a),
                                jnp.asarray(a).dtype), example)
        return {"store": store, "prio": jnp.zeros((self.chunk,)),
                "ptr": jnp.zeros((), jnp.int32),
                "size": jnp.zeros((), jnp.int32)}

    def add_batch(self, state, batch, priorities=None):
        """Identical global ring plan on every member; each writes only
        its owned rows. Bitwise the flat `PrioritizedReplay.add_batch`
        per slice."""
        n = jax.tree_util.tree_leaves(batch)[0].shape[0]
        idx, batch, priorities, ptr = _ring_fit(state, batch,
                                                self.capacity, priorities)
        loc, _ = self._local(idx)
        store = jax.tree_util.tree_map(
            lambda s, b: s.at[loc].set(b, mode="drop"),
            state["store"], batch)
        if priorities is None:  # new samples get max priority (Ape-X)
            gmax = jax.lax.pmax(state["prio"].max(), self.axis)
            priorities = jnp.full((idx.shape[0],), jnp.maximum(gmax, 1.0))
        prio = state["prio"].at[loc].set(priorities, mode="drop")
        return {"store": store, "prio": prio, "ptr": ptr,
                "size": jnp.minimum(state["size"] + n, self.capacity)}

    def sample(self, state, key, n):
        """-> (batch, GLOBAL idx, is_weights), every member returning
        the identical values — draw-for-draw the flat fused path given
        the same key."""
        r = jax.lax.axis_index(self.axis)
        # same key on every member -> same global Gumbel vector; each
        # member consumes its slice, so concatenated scores match the
        # flat draw bitwise
        gumbel = jax.random.gumbel(key, (self.capacity,))
        g_loc = jax.lax.dynamic_slice_in_dim(gumbel, r * self.chunk,
                                             self.chunk)
        nvalid = jnp.maximum(state["size"], 1)
        # the max(size, 1) guard is GLOBAL: slot 0 of shard 0 stands in
        # when the buffer is empty; other shards contribute only -inf
        local_valid = jnp.clip(nvalid - r * self.chunk, 0, self.chunk)
        k = min(n, self.chunk)
        s, li = shard_gumbel_topk(state["prio"], local_valid, g_loc, k,
                                  self.alpha, self.eps,
                                  use_kernel=self.fused)
        cand_s = all_gather_shards(s, self.axis)            # (R*k,)
        cand_i = all_gather_shards(li + r * self.chunk, self.axis)
        _, pos = jax.lax.top_k(cand_s, n)
        idx = cand_i[pos]
        idx = jnp.where(jnp.arange(n) < nvalid, idx, idx[0]).astype(
            jnp.int32)
        # IS weights against the GLOBAL priority mass: gather-per-use
        # of the (capacity,) priorities (~1/elem-size of store bytes),
        # then the ref weight expressions verbatim
        prio_full = all_gather_shards(state["prio"], self.axis)
        w = prioritized_weights_ref(prio_full, state["size"], idx,
                                    self.alpha, self.beta, self.eps)
        loc, own = self._local(idx)
        batch = jax.tree_util.tree_map(
            # foreign rows of the local gather are garbage; psum_select
            # masks them to zero and sums in the owner's true row
            lambda s: psum_select(s[loc], own, self.axis),
            state["store"])
        return batch, idx, w

    def update_priorities(self, state, idx, td_errors):
        """Write-back routed to the owning shard; degenerate duplicate
        indices carry identical values (surplus positions repeat the
        top draw), so the duplicate scatter is deterministic exactly as
        on the flat buffer."""
        loc, _ = self._local(idx)
        prio = state["prio"].at[loc].set(jnp.abs(td_errors) + self.eps,
                                         mode="drop")
        return dict(state, prio=prio)

    # ---- host layout (Trainer / checkpoint seam) ---------------------
    def shard_state(self, state):
        """Flat host buffer state (capacity-sized leaves, the form
        agents init and checkpoints store) -> host sharded layout: every
        leaf gains a leading (n_shards,) dim for the Trainer to lay out
        along the replay mesh axis (store (R, chunk, ...), prio
        (R, chunk), ptr/size tiled (R,))."""
        R, chunk = self.n_shards, self.chunk
        store = jax.tree_util.tree_map(
            lambda s: s.reshape((R, chunk) + s.shape[1:]),
            state["store"])
        return {"store": store,
                "prio": state["prio"].reshape(R, chunk),
                "ptr": jnp.broadcast_to(state["ptr"], (R,)),
                "size": jnp.broadcast_to(state["size"], (R,))}

    def unshard_state(self, state):
        """Inverse of `shard_state`: reassemble the flat host buffer so
        fit()/checkpoints stay plan-independent."""
        store = jax.tree_util.tree_map(
            lambda s: s.reshape((self.capacity,) + s.shape[2:]),
            state["store"])
        return {"store": store, "prio": state["prio"].reshape(-1),
                "ptr": state["ptr"][0], "size": state["size"][0]}
