"""Unified Agent protocol + registry — the survey's actor/learner seam.

Every algorithm — on-policy (PPO/A3C), off-policy-corrected (IMPALA) and
replay-based (DQN) — trains behind the same three methods, so one driver
(`repro.core.trainer.Trainer`) can compose any algorithm with any system
topology (§3) and synchronization mechanism (§6) instead of hard-coding
one composition per algorithm:

    init(key)                  -> TrainState   (registered pytree)
    actor_policy(state, delay) -> behavior params for the rollout engine,
                                  `delay` learner-updates old (policy lag)
    learner_step(state, traj, boot_obs, key, grad_tx, param_tx)
                               -> (TrainState, metrics)

`grad_tx` / `param_tx` are the topology hooks: the Trainer injects
`topology.exchange_grads` (ps/allreduce) and `topology.gossip_mix`
(gossip) there, so agents stay topology-agnostic. Policy lag is carried
as a ring of stacked actor params inside TrainState; §6's bsp/asp/ssp
become schedules over the `delay` argument.

Algorithms self-register by name when `repro.core.algos` is imported;
`make("impala", env=env, ...)` constructs one from config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class PartitionList(list):
    """Marker type for a *per-block* partition: the optimizer target
    split into independently shardable entries (layer-wise ZeRO-3).
    Entry order is transformer blocks first, the non-block remainder
    last. Each entry runs through `flatten_and_pad` on its own, so a
    ZeRO-3 wrapper can gather → use → drop one block at a time instead
    of materializing the whole flattened vector per use."""


def flatten_and_pad(tree, n_shards: int):
    """Flatten a pytree to ONE 1-D vector zero-padded to a multiple of
    `n_shards` — the default partitioning for ZeRO-style learner-state
    sharding: any params pytree becomes `n_shards` equal contiguous
    chunks with no per-algorithm partitioning code.

    Returns ``(vec, size, unravel)``: `vec` the padded vector (its
    length divides evenly by `n_shards` by construction), `size` the
    true unpadded length, and ``unravel(vec[:size])`` restores the
    pytree. Mixed-dtype trees follow ravel_pytree's promotion; all
    agents here carry uniform f32 learner params."""
    vec, unravel = ravel_pytree(tree)
    if vec.size == 0:
        raise ValueError("cannot shard an empty parameter pytree")
    pad = (-vec.size) % n_shards
    if pad:
        vec = jnp.concatenate([vec, jnp.zeros((pad,), vec.dtype)])
    return vec, vec.size - pad, unravel


@dataclasses.dataclass
class TrainState:
    """The unified train-state pytree every algorithm flows through."""
    params: Any      # learner params (whole algorithm-specific pytree)
    opt_state: Any
    extra: Any       # algorithm-private state (replay buffer, ...)
    ring: Any        # (D+1, ...) stacked actor-param history, [0]=newest
    steps: Any       # int32 learner-update counter


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=("params", "opt_state", "extra", "ring", "steps"),
    meta_fields=())


class Agent:
    """Base class: the lag-ring plumbing shared by all agents.

    Subclasses set `self.policy` (an object with `sample`/`apply` for the
    rollout engine) and `self.ring_size`, and implement `init` and
    `learner_step`. `behavior_params` picks the sub-tree actors need
    (default: the whole params pytree)."""

    policy: Any
    ring_size: int = 1

    # -- protocol ------------------------------------------------------
    def init(self, key) -> TrainState:
        raise NotImplementedError

    def learner_step(self, state, traj, boot_obs, key,
                     grad_tx=None, param_tx=None):
        raise NotImplementedError

    def actor_policy(self, state: TrainState, delay=0):
        """Behavior params `delay` learner-updates old (clipped to the
        ring depth) — §6 sync mechanisms are schedules over `delay`."""
        return self._ring_read(state.ring, delay)

    def partition_spec(self, state: TrainState):
        """The sub-pytree of `state` the optimizer updates — what
        `opt_state` mirrors and what a ZeRO `shard`-role mesh axis
        partitions (`flatten_and_pad` turns it into equal chunks, so
        any pytree shards without per-algorithm partitioning code).
        Default: the whole params pytree; override when the optimizer
        targets a subtree (see DQNAgent: only the online net)."""
        return state.params

    def replace_partition(self, params, sub):
        """Inverse of `partition_spec` on the params pytree: return
        `params` with the optimizer-target subtree replaced by `sub`.
        ZeRO-3 uses this pair to split params into a sharded chunk
        (the partition) plus an unsharded rest, and to graft a gathered
        partition back in per use. Default (partition == whole tree):
        the rest is empty, so the grafted tree IS `sub`."""
        return sub

    def partition_list(self, part):
        """Optionally split the optimizer-target pytree `part` (the
        value `partition_spec` returns, or any congruent tree such as
        one actor-ring slot) into per-block entries for layer-wise
        ZeRO-3: a `PartitionList` of [block_0, ..., block_{R-1},
        remainder]. Default consults the policy's `partition_list` hook
        (TrunkPolicy: one entry per superblock of the scan stack plus
        the non-block remainder). Returns None when the policy exposes
        no block structure — list-free agents (MLP policies, DQN's
        q-net adapter) then fall back to the single-partition path
        bitwise-unchanged."""
        split = getattr(self.policy, "partition_list", None)
        if split is None:
            return None
        parts = split(part)
        return None if parts is None else PartitionList(parts)

    def merge_partition_list(self, entries, materialize=False):
        """Inverse of `partition_list` (policy hook). With
        `materialize=False` the block entries stay a Python list — the
        lazy form the trunk's `_run_seq` consumes one block at a time
        (gather → run → drop); `materialize=True` restacks them into
        the canonical stacked layout for host/checkpoint forms."""
        return self.policy.merge_partition_list(entries,
                                                materialize=materialize)

    # -- lag-ring helpers ----------------------------------------------
    def _ring_init(self, behavior_params):
        return jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p, (self.ring_size,) + p.shape),
            behavior_params)

    def _ring_read(self, ring, delay):
        d = jnp.minimum(jnp.asarray(delay, jnp.int32), self.ring_size - 1)
        return jax.tree_util.tree_map(
            lambda r: jnp.take(r, d, axis=0), ring)

    def _ring_push(self, ring, behavior_params):
        return jax.tree_util.tree_map(
            lambda h, p: jnp.roll(h, 1, axis=0).at[0].set(p),
            ring, behavior_params)


class PolicyGradientAgent(Agent):
    """Shared init/learner_step for agents whose learner is one
    `value_and_grad` over ``self.algo.loss(params, traj, boot_obs)``
    (A3C, IMPALA; PPO reuses `init` and overrides `learner_step`).
    Subclasses' __init__ must set `policy`, `algo`, `opt`, `ring_size`."""

    def init(self, key):
        params = self.policy.init(key)
        return TrainState(params, self.opt.init(params), {},
                          self._ring_init(params), jnp.zeros((), jnp.int32))

    def learner_step(self, state, traj, boot_obs, key,
                     grad_tx=None, param_tx=None):
        loss, grads = jax.value_and_grad(self.algo.loss)(
            state.params, traj, boot_obs)
        if grad_tx is not None:
            grads = grad_tx(grads)
        params, opt_state = self.opt.apply(state.params, state.opt_state,
                                           grads)
        if param_tx is not None:
            params = param_tx(params)
        return TrainState(params, opt_state, state.extra,
                          self._ring_push(state.ring, params),
                          state.steps + 1), {"loss": loss}


# ------------------------------------------------------------ registry
_REGISTRY: Dict[str, Callable[..., Agent]] = {}


def register(name: str, factory: Callable[..., Agent]) -> None:
    """Register an Agent factory under `name` (called with env=..., **kw)."""
    _REGISTRY[name] = factory


def available():
    """Names of all registered algorithms."""
    import repro.core.algos  # noqa: F401 — triggers self-registration
    return tuple(sorted(_REGISTRY))


def make(name: str, env, **kwargs) -> Agent:
    """Construct a registered algorithm by name from config. The Trainer
    passes `ring_size` (actor-param history depth) and `total_iters`
    (training horizon, for schedules like DQN's ε-anneal) alongside any
    user algo_kwargs; factories accept and may ignore them."""
    import repro.core.algos  # noqa: F401 — triggers self-registration
    if name not in _REGISTRY:
        raise KeyError(f"unknown algorithm {name!r}; available: "
                       f"{', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[name](env=env, **kwargs)
