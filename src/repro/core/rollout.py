"""Actor-side vectorized rollout engine (survey §3 Actor role).

One jitted `lax.scan` advances B environments T steps: policy inference,
env dynamics and auto-reset all fuse into a single XLA program — the
zero-copy batch-simulation pipeline of survey §4.2/Fig. 5(b).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def rollout(policy, params, env, key, env_state, T):
    """Collect T steps from a batch of envs.

    Returns (trajectory, final_env_state). trajectory arrays are
    time-major (T, B, ...): obs, action, logp, value, reward, done,
    next_obs. `next_obs` is the TRUE successor observation — at `done`
    steps it is the pre-autoreset terminal obs (see
    Env.step_autoreset), so replay/bootstrap consumers never see the
    fresh-reset obs at an episode boundary.

    Policies exposing `sample_value` (one forward for action, log-prob
    AND value) get exactly one network evaluation per env step; the
    legacy sample + apply pair is kept only as a fallback for policies
    without it.
    """
    sample_value = getattr(policy, "sample_value", None)
    if sample_value is None:
        def sample_value(params, obs, key):   # two-forward fallback
            action, logp = policy.sample(params, obs, key)
            _, value = policy.apply(params, obs)
            return action, logp, value

    def step(carry, key_t):
        env_state = carry
        obs = jax.vmap(env.obs)(env_state)
        ka, kr = jax.random.split(key_t)
        action, logp, value = sample_value(params, obs, ka)
        env_state, next_obs, reward, done = env.step_autoreset(
            env_state, action, kr)
        return env_state, {"obs": obs, "action": action, "logp": logp,
                           "value": value, "reward": reward, "done": done,
                           "next_obs": next_obs}

    keys = jax.random.split(key, T)
    env_state, traj = jax.lax.scan(step, env_state, keys)
    return traj, env_state


@functools.partial(jax.jit, static_argnames=("policy", "env", "T", "n"))
def rollout_fresh(policy, params, env, key, T, n):
    """Rollout from freshly-reset envs (jitted end-to-end)."""
    k0, k1 = jax.random.split(key)
    env_state = env.reset_batch(k0, n)
    return rollout(policy, params, env, k1, env_state, T)


def episode_return(policy, params, env, key, max_steps=200):
    """Deterministic-ish single-episode return (greedy for discrete,
    mean action for continuous) — the ES/GA fitness function. The mean
    continuous action is squashed into the env's action box read off
    its EnvSpec (no hard-coded torque bounds)."""
    state = env.reset(key)
    act_space = env.spec.action

    def step(carry, _):
        state, done, total = carry
        obs = env.obs(state)
        pi, _ = policy.apply(params, obs)
        if policy.discrete:
            action = jnp.argmax(pi, axis=-1)
        else:
            action = (act_space.midpoint
                      + jnp.tanh(pi) * act_space.half_range)
        nstate, _, reward, ndone = env.step(state, action)
        total = total + jnp.where(done, 0.0, reward)
        ndone = done | ndone
        state = jax.tree_util.tree_map(
            lambda a, b: jnp.where(done, a, b), state, nstate)
        return (state, ndone, total), None

    (_, _, total), _ = jax.lax.scan(
        step, (state, jnp.zeros((), bool), jnp.zeros(())),
        None, length=max_steps)
    return total
