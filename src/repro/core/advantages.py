"""Advantage estimation (GAE / n-step returns) — public API.

Mirrors core/vtrace.py: dispatches to the Pallas reverse-scan kernel on
TPU and the lax.scan reference elsewhere; both share the oracle in
kernels/advantages/ref.py. PPO (`algos/ppo.py`) and A3C
(`algos/a3c.py`) compute their targets through this seam instead of
private inline scans, so every learner's serial T-recursion runs
through one kernel family.
"""
from repro.kernels.common import interpret_mode
from repro.kernels.advantages.ref import (discounted_return_ref, gae_ref,
                                          nstep_return_ref)


def discounted_return(base, coef, init, use_kernel=False):
    """out_t = base_t + coef_t * out_{t+1}; time-major (T, B)."""
    if use_kernel and not interpret_mode():
        from repro.kernels.advantages.ops import discounted_return as k
        return k(base, coef, init)
    return discounted_return_ref(base, coef, init)


def gae(rewards, values, dones, bootstrap, gamma=0.99, lam=0.95,
        use_kernel=False):
    """Generalized advantage estimation, time-major (T, B).
    Returns (advantages, returns)."""
    if use_kernel and not interpret_mode():
        from repro.kernels.advantages.ops import gae as gae_k
        return gae_k(rewards, values, dones, bootstrap, gamma, lam)
    return gae_ref(rewards, values, dones, bootstrap, gamma, lam)


def nstep_return(rewards, dones, bootstrap, gamma=0.99, use_kernel=False):
    """Discounted n-step returns, time-major (T, B) -> (T, B)."""
    if use_kernel and not interpret_mode():
        from repro.kernels.advantages.ops import nstep_return as k
        return k(rewards, dones, bootstrap, gamma)
    return nstep_return_ref(rewards, dones, bootstrap, gamma)
