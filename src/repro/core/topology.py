"""System-architecture topologies (survey §3, Fig. 3) as gradient/param
exchange strategies over a named mesh axis, usable inside shard_map.

  * `allreduce` — decentralized (IMPALA/rlpyt/DD-PPO): lax.pmean; lowers
    to all-reduce over the ring.
  * `ps` — centralized parameter-server star: every worker all-gathers
    the raw gradients then reduces locally. Mathematically identical to
    all-reduce but lowers to a gather+broadcast collective schedule —
    the honest SPMD rendering of the star topology (DESIGN.md §4.2),
    and measurably worse in collective bytes (benchmarks/fig3).
  * `gossip` — peer-to-peer (GALA, survey §3.3): no gradient exchange;
    instead params are averaged with the ring neighbour each step via
    lax.ppermute. Workers' models stay ε-close rather than identical
    (property-tested in tests/test_topology.py).

Beside the gradient-exchange strategies live the ZeRO-2 learner-state
sharding pieces for `shard`-role DistPlan axes: reduce-scatter /
all-gather helpers (`local_shard` / `reduce_scatter_mean` /
`all_gather_shards`) and `zero_sharded_optimizer`, which partitions any
optimizer's state 1/n per device over a mesh axis while keeping params
replicated (survey §5 memory ceiling; SRL / Stooke & Abbeel's
large-batch learner split) — plus `ZeRO3Agent`, the full ZeRO-3
gather-per-use wrapper for `zero3`-role axes (params stored sharded
too, all-gathered per use inside learner_step/actor_policy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

TOPOLOGIES = ("allreduce", "ps", "gossip")


def exchange_grads(grads, axis, topology: str):
    """Aggregate per-worker grads according to the topology; `axis` is a
    mesh axis name or (for a fused hierarchical allreduce) a tuple of
    names, outermost first. For gossip, grads are returned unchanged
    (aggregation happens on params)."""
    if topology == "allreduce":
        return jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis), grads)
    if topology == "ps":
        def star(g):
            gathered = jax.lax.all_gather(g, axis)   # star: to the center
            return jnp.mean(gathered, axis=0)        # PS reduce+broadcast
        return jax.tree_util.tree_map(star, grads)
    if topology == "gossip":
        return grads
    raise ValueError(topology)


def gossip_mix(params, axis: str, hops: int = 1):
    """One gossip round: average params with the ring neighbour(s)."""
    n = jax.lax.psum(1, axis)  # static axis size (jax.lax.axis_size
    #                            does not exist in this jax version)
    mixed = params
    for h in range(hops):
        d = 2 ** h
        perm = [(i, (i + d) % n) for i in range(n)]
        nbr = jax.tree_util.tree_map(
            lambda p: jax.lax.ppermute(p, axis, perm), mixed)
        mixed = jax.tree_util.tree_map(
            lambda a, b: 0.5 * (a + b), mixed, nbr)
    return mixed


# ---- ZeRO-style learner-state sharding (shard-role mesh axes) --------
def local_shard(vec, axis: str, n_shards: int):
    """This device's 1/n contiguous chunk of a (padded) 1-D vector —
    the scatter half of a reduce-scatter, indexed by the device's
    position on mesh axis `axis`."""
    chunk = vec.shape[0] // n_shards
    i = jax.lax.axis_index(axis)
    return jax.lax.dynamic_slice_in_dim(vec, i * chunk, chunk)


def reduce_scatter_mean(vec, axis: str, n_shards: int):
    """Mean-reduce `vec` over `axis`, keeping only the local 1/n chunk
    (ZeRO-2's gradient exchange). Rendered as the fused pmean + local
    slice — bitwise the replicated reduction, the same honest-SPMD
    argument as `ps` vs `allreduce` above; a raw `psum_scatter` lowers
    to fewer bytes but reorders the reduction and would break the
    shard-size-1 bitwise guarantee (tests/test_trainer.py). Inside the
    Trainer the pmean half is already fused into `grad_tx` (the shard
    axis is a mandatory `allreduce`), so only `local_shard` runs there."""
    return local_shard(jax.lax.pmean(vec, axis), axis, n_shards)


def all_gather_shards(chunk, axis: str):
    """Inverse of `local_shard`: tiled all-gather concatenating every
    device's chunk in axis-index order back into the full vector."""
    return jax.lax.all_gather(chunk, axis, tiled=True)


def psum_select(rows, own, axis: str):
    """Owner-routed row assembly for the sharded replay service: `rows`
    (n, ...) is each member's local gather (garbage where it doesn't own
    the slot), `own` (n,) bool marks the rows this member owns. Each row
    is owned by exactly ONE member of `axis`, so the masked psum adds
    the true row to zeros from everyone else — x + 0 is exact, keeping
    assembly bitwise a local gather from the full buffer. Bool leaves
    ride through int32 (psum has no bool reduction)."""
    mask = own.reshape((-1,) + (1,) * (rows.ndim - 1))
    if jnp.issubdtype(rows.dtype, jnp.bool_):
        picked = jnp.where(mask, rows, False)
        return jax.lax.psum(picked.astype(jnp.int32),
                            axis).astype(jnp.bool_)
    picked = jnp.where(mask, rows, jnp.zeros((), rows.dtype))
    return jax.lax.psum(picked, axis)


@dataclasses.dataclass(frozen=True)
class ZeROShardedOptimizer:
    """ZeRO-2 discipline over mesh axis `axis`: wraps any Optimizer-like
    object (init/update/apply, optional pre/shard_update split — see
    repro.optim.Optimizer) so the optimizer state lives flattened-and-
    padded 1/n per device while params stay replicated.

    `apply(params, opt_state, grads)` expects grads ALREADY mean-reduced
    over `axis` (inside the Trainer that pmean is fused into `grad_tx`,
    making pmean+`local_shard` a reduce-scatter); it then

      1. runs the optimizer's `pre` transform — the part that must see
         the FULL gradient pytree, e.g. global-norm clipping — on the
         unsharded grads,
      2. flattens-and-pads grads and params and takes the local 1/n
         chunk (the scatter),
      3. applies the per-coordinate update on the slice against the
         local `opt_state` shard,
      4. all-gathers the updated param chunks back into the full,
         replicated params pytree before the next rollout.

    Every step is per-coordinate or a deterministic concatenation, so a
    sharded fit is f32-bitwise the replicated fit (and a shard axis of
    size 1 is a bitwise no-op) — pinned in tests/test_trainer.py.

    `init(params)` returns the inner state over ONE all-zero chunk:
    since every shard's moments start at zero, the Trainer's plain
    replicate-then-split path seeds each device's shard correctly and
    the chunks diverge naturally as training proceeds."""
    inner: object
    axis: str
    n_shards: int

    def init(self, params):
        from repro.core.agent import flatten_and_pad
        if self.n_shards == 1:
            return self.inner.init(params)
        vec, _, _ = flatten_and_pad(params, self.n_shards)
        chunk = vec.size // self.n_shards
        return self.inner.init(jnp.zeros((chunk,), vec.dtype))

    def apply(self, params, opt_state, grads):
        from repro.core.agent import flatten_and_pad
        if self.n_shards == 1:
            # sharding into one chunk is the identity: delegate to the
            # inner optimizer untouched, so a size-1 shard axis is a
            # bitwise no-op BY CONSTRUCTION (same code path, same
            # pytree-shaped opt_state as the replicated trainer)
            return self.inner.apply(params, opt_state, grads)
        pre = getattr(self.inner, "pre", None)
        bare = (self.inner.shard_update if pre is not None
                else self.inner.update)
        if pre is not None:
            grads = pre(grads)  # full-gradient transform (global norm)
        gvec, _, _ = flatten_and_pad(grads, self.n_shards)
        pvec, size, unravel = flatten_and_pad(params, self.n_shards)
        g_loc = local_shard(gvec, self.axis, self.n_shards)
        p_loc = local_shard(pvec, self.axis, self.n_shards)
        updates, opt_state = bare(g_loc, opt_state, p_loc)
        full = all_gather_shards(p_loc + updates, self.axis)
        return unravel(full[:size]), opt_state


def zero_sharded_optimizer(opt, axis: str, n_shards: int):
    """Wrap `opt` for ZeRO-2 execution over mesh axis `axis` (see
    ZeROShardedOptimizer). The Trainer installs this on the agent's
    optimizer whenever its DistPlan carries a `shard`-role axis."""
    return ZeROShardedOptimizer(opt, axis, n_shards)


class ZeRO3Agent:
    """Full ZeRO-3 discipline over mesh axis `axis`, as an Agent wrapper
    (DistPlan role ``zero3``): the inner agent's optimizer-target params
    (`partition_spec`) are STORED flattened-and-padded 1/n per device in
    TrainState and all-gathered *per use* — gather → compute → drop —
    inside both `learner_step` and `actor_policy`, instead of ZeRO-2's
    persistent replicated copy. The actor-param lag ring is stored as
    chunks too, so per-device params+opt_state+ring bytes all shrink
    toward 1/n.

    Wrapper-form TrainState layout (per device, inside shard_map):

        params    {"zero3": (chunk,) this device's param chunk,
                   "rest":  inner params with the partition removed
                            (`replace_partition(params, None)`)}
        ring      (ring_size, chunk) chunked actor-param history
        opt_state untouched (the inner opt is already the ZeRO-2
                  wrapper, so its state is chunk-shaped)

    Every transform is a deterministic concatenation or slice and
    `all_gather_shards ∘ local_shard` is the identity on the padded
    vector, so a ZeRO-3 fit is f32-bitwise the replicated fit and a
    size-1 shard axis is a bitwise no-op (pinned, same discipline as
    ZeRO-2, in tests/test_trainer.py). `host_state` reassembles a
    host-layout wrapper state back to the inner agent's replicated tree
    form — checkpoints and ParamStore templates stay plan-independent.

    `init` returns HOST layout: chunked leaves carry a leading
    (n_shards,) dim (params["zero3"] (n_shards, chunk); ring
    (n_shards, ring_size, chunk)) which the Trainer lays out along the
    shard mesh axis (`Trainer._lay_out_zero3`)."""

    def __init__(self, inner, axis: str, n_shards: int):
        self.inner = inner
        self.axis = axis
        self.n_shards = n_shards
        self.policy = inner.policy
        self.ring_size = inner.ring_size
        self.opt = inner.opt

    # -- layout plumbing ----------------------------------------------
    def _flatten(self, tree):
        from repro.core.agent import flatten_and_pad
        return flatten_and_pad(tree, self.n_shards)

    def _gather(self, chunk):
        """chunk (chunk,) -> the partition pytree (gather-per-use)."""
        vec = all_gather_shards(chunk, self.axis)
        return self._unravel(vec[:self._size])

    def is_wrapper_state(self, state) -> bool:
        """True for wrapper-form TrainStates (chunked params); False for
        inner/reassembled form (checkpoint restores, fit() output)."""
        return isinstance(state.params, dict) and "zero3" in state.params

    # -- Agent protocol ------------------------------------------------
    def partition_spec(self, state):
        if self.is_wrapper_state(state):
            return state.params["zero3"]
        return self.inner.partition_spec(state)

    def replace_partition(self, params, sub):
        return self.inner.replace_partition(params, sub)

    def init(self, key):
        from repro.core.agent import TrainState
        st = self.inner.init(key)
        part = self.inner.partition_spec(st)
        vec, size, unravel = self._flatten(part)
        self._size, self._padded = int(size), int(vec.size)
        self._chunk = self._padded // self.n_shards
        self._unravel = unravel
        slot0 = jax.tree_util.tree_map(lambda r: r[0], st.ring)
        if (jax.tree_util.tree_structure(part)
                != jax.tree_util.tree_structure(slot0)):
            raise ValueError(
                "ZeRO-3 requires the actor ring to store the same pytree "
                "as partition_spec (the behavior params ARE the sharded "
                "partition); got differing structures")
        ring = jnp.stack([self._flatten(
            jax.tree_util.tree_map(lambda r: r[d], st.ring))[0]
            .reshape(self.n_shards, self._chunk)
            for d in range(self.ring_size)], axis=1)
        params = {"zero3": vec.reshape(self.n_shards, self._chunk),
                  "rest": self.inner.replace_partition(st.params, None)}
        return TrainState(params, st.opt_state, st.extra, ring, st.steps)

    def learner_step(self, state, traj, boot_obs, key,
                     grad_tx=None, param_tx=None):
        from repro.core.agent import TrainState
        sub = self._gather(state.params["zero3"])
        params = self.inner.replace_partition(state.params["rest"], sub)
        # dummy full ring: the inner step's ring push is discarded (the
        # chunk ring below is authoritative), so XLA DCEs the broadcast
        ring = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p, (self.ring_size,) + p.shape),
            sub)
        new, metrics = self.inner.learner_step(
            TrainState(params, state.opt_state, state.extra, ring,
                       state.steps),
            traj, boot_obs, key, grad_tx=grad_tx, param_tx=param_tx)
        nvec, _, _ = self._flatten(self.inner.partition_spec(new))
        chunk = local_shard(nvec, self.axis, self.n_shards)
        ring_c = jnp.roll(state.ring, 1, axis=0).at[0].set(chunk)
        params = {"zero3": chunk,
                  "rest": self.inner.replace_partition(new.params, None)}
        return (TrainState(params, new.opt_state, new.extra, ring_c,
                           new.steps), metrics)

    def actor_policy(self, state, delay=0):
        from repro.core.agent import TrainState
        if not self.is_wrapper_state(state):
            # reassembled form (fit() output / checkpoint restore, e.g.
            # via ParamStore.publish_from_state) — inner handles it
            return self.inner.actor_policy(state, delay)
        d = jnp.minimum(jnp.asarray(delay, jnp.int32), self.ring_size - 1)
        sub = self._gather(jnp.take(state.ring, d, axis=0))
        ring1 = jax.tree_util.tree_map(lambda p: p[None], sub)
        # delay resolved above; inner may still read steps (DQN ε-anneal)
        return self.inner.actor_policy(
            TrainState(None, None, None, ring1, state.steps), 0)

    def host_state(self, state):
        """Reassemble a HOST-layout wrapper TrainState (leading
        (n_shards,) dims on chunked leaves, no mesh dims) into the inner
        agent's replicated tree form, with a template-shaped opt_state —
        `checkpoint.load_train_state` and `ParamStore.publish_from_state`
        route templates through this so they stay plan-independent.
        Inner-form states pass through unchanged."""
        from repro.core.agent import TrainState
        if not self.is_wrapper_state(state):
            return state
        sub = self._unravel(
            state.params["zero3"].reshape(-1)[:self._size])
        params = self.inner.replace_partition(state.params["rest"], sub)
        slots = [self._unravel(
            state.ring[:, d, :].reshape(-1)[:self._size])
            for d in range(self.ring_size)]
        ring = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *slots)
        opt = getattr(self.inner.opt, "inner", self.inner.opt)
        return TrainState(params, opt.init(sub), state.extra, ring,
                          state.steps)


def strip_worker_dim(tree, n: int = 1):
    """Drop the `n` length-1 leading mesh dims shard_map keeps on leaves
    (one per sharded mesh axis; n=1 is the legacy 1-D worker axis)."""
    axes = tuple(range(n))
    return jax.tree_util.tree_map(lambda a: jnp.squeeze(a, axes), tree)


def restore_worker_dim(tree, n: int = 1):
    """Re-add `n` length-1 leading mesh dims for shard_map outputs."""
    axes = tuple(range(n))
    return jax.tree_util.tree_map(
        lambda a: jnp.expand_dims(a, axes), tree)


def make_distributed_step(loss_fn, optimizer, topology: str, mesh,
                          axis: str = "workers"):
    """Build a jitted multi-worker training step over `mesh[axis]`.

    Worker-local state: (params, opt_state). Batch is sharded over the
    worker axis. allreduce/ps keep replicas bit-identical; gossip lets
    them drift ε-close.
    """
    from jax.experimental.shard_map import shard_map

    def worker_step(params, opt_state, batch):
        # shard_map keeps the (length-1) worker dim — strip and restore
        sq, ex = strip_worker_dim, restore_worker_dim
        params, opt_state, batch = sq(params), sq(opt_state), sq(batch)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = exchange_grads(grads, axis, topology)
        params, opt_state = optimizer.apply(params, opt_state, grads)
        if topology == "gossip":
            params = gossip_mix(params, axis)
        return ex(params), ex(opt_state), jax.lax.pmean(loss, axis)

    # params replicated per-worker => leading worker axis on every leaf
    pspec = P(axis)
    step = shard_map(worker_step, mesh=mesh,
                     in_specs=(pspec, pspec, pspec),
                     out_specs=(pspec, pspec, P()),
                     check_rep=False)
    return jax.jit(step)


def replicate_for(mesh, axis, params):
    """Stack params with leading replica dim(s) — one per mesh axis in
    `axis` (a name or tuple of names, outermost first)."""
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    shape = tuple(mesh.shape[a] for a in names)
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, shape + p.shape), params)
