"""System-architecture topologies (survey §3, Fig. 3) as gradient/param
exchange strategies over a named mesh axis, usable inside shard_map.

  * `allreduce` — decentralized (IMPALA/rlpyt/DD-PPO): lax.pmean; lowers
    to all-reduce over the ring.
  * `ps` — centralized parameter-server star: every worker all-gathers
    the raw gradients then reduces locally. Mathematically identical to
    all-reduce but lowers to a gather+broadcast collective schedule —
    the honest SPMD rendering of the star topology (DESIGN.md §4.2),
    and measurably worse in collective bytes (benchmarks/fig3).
  * `gossip` — peer-to-peer (GALA, survey §3.3): no gradient exchange;
    instead params are averaged with the ring neighbour each step via
    lax.ppermute. Workers' models stay ε-close rather than identical
    (property-tested in tests/test_topology.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

TOPOLOGIES = ("allreduce", "ps", "gossip")


def exchange_grads(grads, axis, topology: str):
    """Aggregate per-worker grads according to the topology; `axis` is a
    mesh axis name or (for a fused hierarchical allreduce) a tuple of
    names, outermost first. For gossip, grads are returned unchanged
    (aggregation happens on params)."""
    if topology == "allreduce":
        return jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis), grads)
    if topology == "ps":
        def star(g):
            gathered = jax.lax.all_gather(g, axis)   # star: to the center
            return jnp.mean(gathered, axis=0)        # PS reduce+broadcast
        return jax.tree_util.tree_map(star, grads)
    if topology == "gossip":
        return grads
    raise ValueError(topology)


def gossip_mix(params, axis: str, hops: int = 1):
    """One gossip round: average params with the ring neighbour(s)."""
    n = jax.lax.psum(1, axis)  # static axis size (jax.lax.axis_size
    #                            does not exist in this jax version)
    mixed = params
    for h in range(hops):
        d = 2 ** h
        perm = [(i, (i + d) % n) for i in range(n)]
        nbr = jax.tree_util.tree_map(
            lambda p: jax.lax.ppermute(p, axis, perm), mixed)
        mixed = jax.tree_util.tree_map(
            lambda a, b: 0.5 * (a + b), mixed, nbr)
    return mixed


def strip_worker_dim(tree, n: int = 1):
    """Drop the `n` length-1 leading mesh dims shard_map keeps on leaves
    (one per sharded mesh axis; n=1 is the legacy 1-D worker axis)."""
    axes = tuple(range(n))
    return jax.tree_util.tree_map(lambda a: jnp.squeeze(a, axes), tree)


def restore_worker_dim(tree, n: int = 1):
    """Re-add `n` length-1 leading mesh dims for shard_map outputs."""
    axes = tuple(range(n))
    return jax.tree_util.tree_map(
        lambda a: jnp.expand_dims(a, axes), tree)


def make_distributed_step(loss_fn, optimizer, topology: str, mesh,
                          axis: str = "workers"):
    """Build a jitted multi-worker training step over `mesh[axis]`.

    Worker-local state: (params, opt_state). Batch is sharded over the
    worker axis. allreduce/ps keep replicas bit-identical; gossip lets
    them drift ε-close.
    """
    from jax.experimental.shard_map import shard_map

    def worker_step(params, opt_state, batch):
        # shard_map keeps the (length-1) worker dim — strip and restore
        sq, ex = strip_worker_dim, restore_worker_dim
        params, opt_state, batch = sq(params), sq(opt_state), sq(batch)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = exchange_grads(grads, axis, topology)
        params, opt_state = optimizer.apply(params, opt_state, grads)
        if topology == "gossip":
            params = gossip_mix(params, axis)
        return ex(params), ex(opt_state), jax.lax.pmean(loss, axis)

    # params replicated per-worker => leading worker axis on every leaf
    pspec = P(axis)
    step = shard_map(worker_step, mesh=mesh,
                     in_specs=(pspec, pspec, pspec),
                     out_specs=(pspec, pspec, P()),
                     check_rep=False)
    return jax.jit(step)


def replicate_for(mesh, axis, params):
    """Stack params with leading replica dim(s) — one per mesh axis in
    `axis` (a name or tuple of names, outermost first)."""
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    shape = tuple(mesh.shape[a] for a in names)
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, shape + p.shape), params)
