"""System-architecture topologies (survey §3, Fig. 3) as gradient/param
exchange strategies over a named mesh axis, usable inside shard_map.

  * `allreduce` — decentralized (IMPALA/rlpyt/DD-PPO): lax.pmean; lowers
    to all-reduce over the ring.
  * `ps` — centralized parameter-server star: every worker all-gathers
    the raw gradients then reduces locally. Mathematically identical to
    all-reduce but lowers to a gather+broadcast collective schedule —
    the honest SPMD rendering of the star topology (DESIGN.md §4.2),
    and measurably worse in collective bytes (benchmarks/fig3).
  * `gossip` — peer-to-peer (GALA, survey §3.3): no gradient exchange;
    instead params are averaged with the ring neighbour each step via
    lax.ppermute. Workers' models stay ε-close rather than identical
    (property-tested in tests/test_topology.py).

Beside the gradient-exchange strategies live the ZeRO-2 learner-state
sharding pieces for `shard`-role DistPlan axes: reduce-scatter /
all-gather helpers (`local_shard` / `reduce_scatter_mean` /
`all_gather_shards`) and `zero_sharded_optimizer`, which partitions any
optimizer's state 1/n per device over a mesh axis while keeping params
replicated (survey §5 memory ceiling; SRL / Stooke & Abbeel's
large-batch learner split) — plus `ZeRO3Agent`, the full ZeRO-3
gather-per-use wrapper for `zero3`-role axes (params stored sharded
too, all-gathered per use inside learner_step/actor_policy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

TOPOLOGIES = ("allreduce", "ps", "gossip")


def exchange_grads(grads, axis, topology: str):
    """Aggregate per-worker grads according to the topology; `axis` is a
    mesh axis name or (for a fused hierarchical allreduce) a tuple of
    names, outermost first. For gossip, grads are returned unchanged
    (aggregation happens on params)."""
    if topology == "allreduce":
        return jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis), grads)
    if topology == "ps":
        def star(g):
            gathered = jax.lax.all_gather(g, axis)   # star: to the center
            return jnp.mean(gathered, axis=0)        # PS reduce+broadcast
        return jax.tree_util.tree_map(star, grads)
    if topology == "gossip":
        return grads
    raise ValueError(topology)


def gossip_mix(params, axis: str, hops: int = 1):
    """One gossip round: average params with the ring neighbour(s)."""
    n = jax.lax.psum(1, axis)  # static axis size (jax.lax.axis_size
    #                            does not exist in this jax version)
    mixed = params
    for h in range(hops):
        d = 2 ** h
        perm = [(i, (i + d) % n) for i in range(n)]
        nbr = jax.tree_util.tree_map(
            lambda p: jax.lax.ppermute(p, axis, perm), mixed)
        mixed = jax.tree_util.tree_map(
            lambda a, b: 0.5 * (a + b), mixed, nbr)
    return mixed


# ---- ZeRO-style learner-state sharding (shard-role mesh axes) --------
def local_shard(vec, axis: str, n_shards: int):
    """This device's 1/n contiguous chunk of a (padded) 1-D vector —
    the scatter half of a reduce-scatter, indexed by the device's
    position on mesh axis `axis`."""
    chunk = vec.shape[0] // n_shards
    i = jax.lax.axis_index(axis)
    return jax.lax.dynamic_slice_in_dim(vec, i * chunk, chunk)


def reduce_scatter_mean(vec, axis: str, n_shards: int):
    """Mean-reduce `vec` over `axis`, keeping only the local 1/n chunk
    (ZeRO-2's gradient exchange). Rendered as the fused pmean + local
    slice — bitwise the replicated reduction, the same honest-SPMD
    argument as `ps` vs `allreduce` above; a raw `psum_scatter` lowers
    to fewer bytes but reorders the reduction and would break the
    shard-size-1 bitwise guarantee (tests/test_trainer.py). Inside the
    Trainer the pmean half is already fused into `grad_tx` (the shard
    axis is a mandatory `allreduce`), so only `local_shard` runs there."""
    return local_shard(jax.lax.pmean(vec, axis), axis, n_shards)


def all_gather_shards(chunk, axis: str):
    """Inverse of `local_shard`: tiled all-gather concatenating every
    device's chunk in axis-index order back into the full vector."""
    return jax.lax.all_gather(chunk, axis, tiled=True)


def psum_select(rows, own, axis: str):
    """Owner-routed row assembly for the sharded replay service: `rows`
    (n, ...) is each member's local gather (garbage where it doesn't own
    the slot), `own` (n,) bool marks the rows this member owns. Each row
    is owned by exactly ONE member of `axis`, so the masked psum adds
    the true row to zeros from everyone else — x + 0 is exact, keeping
    assembly bitwise a local gather from the full buffer. Bool leaves
    ride through int32 (psum has no bool reduction)."""
    mask = own.reshape((-1,) + (1,) * (rows.ndim - 1))
    if jnp.issubdtype(rows.dtype, jnp.bool_):
        picked = jnp.where(mask, rows, False)
        return jax.lax.psum(picked.astype(jnp.int32),
                            axis).astype(jnp.bool_)
    picked = jnp.where(mask, rows, jnp.zeros((), rows.dtype))
    return jax.lax.psum(picked, axis)


@dataclasses.dataclass(frozen=True)
class ZeROShardedOptimizer:
    """ZeRO-2 discipline over mesh axis `axis`: wraps any Optimizer-like
    object (init/update/apply, optional pre/shard_update split — see
    repro.optim.Optimizer) so the optimizer state lives flattened-and-
    padded 1/n per device while params stay replicated.

    `apply(params, opt_state, grads)` expects grads ALREADY mean-reduced
    over `axis` (inside the Trainer that pmean is fused into `grad_tx`,
    making pmean+`local_shard` a reduce-scatter); it then

      1. runs the optimizer's `pre` transform — the part that must see
         the FULL gradient pytree, e.g. global-norm clipping — on the
         unsharded grads,
      2. flattens-and-pads grads and params and takes the local 1/n
         chunk (the scatter),
      3. applies the per-coordinate update on the slice against the
         local `opt_state` shard,
      4. all-gathers the updated param chunks back into the full,
         replicated params pytree before the next rollout.

    Every step is per-coordinate or a deterministic concatenation, so a
    sharded fit is f32-bitwise the replicated fit (and a shard axis of
    size 1 is a bitwise no-op) — pinned in tests/test_trainer.py.

    `init(params)` returns the inner state over ONE all-zero chunk:
    since every shard's moments start at zero, the Trainer's plain
    replicate-then-split path seeds each device's shard correctly and
    the chunks diverge naturally as training proceeds.

    Layer-wise ZeRO-3 (`parts`/`merge` set by `ZeRO3Agent` when the
    agent yields a per-block `PartitionList`): the optimizer target is
    split into entries, opt_state becomes a LIST of per-entry inner
    states over one 1/N chunk each, and `apply` runs flatten → slice →
    update → gather per entry — so no whole-vector params/grads temp is
    ever formed and each updated block can be consumed and dropped by
    the trunk's unrolled loop. The per-coordinate update is identical
    on every coordinate regardless of the chunking, so the entry-wise
    path stays f32-bitwise the whole-vector path."""
    inner: object
    axis: str
    n_shards: int
    parts: object = None   # optional pytree -> [entry, ...] splitter
    merge: object = None   # inverse of `parts` (lazy: stack stays a list)

    def init(self, params):
        from repro.core.agent import flatten_and_pad
        if self.n_shards == 1:
            return self.inner.init(params)
        if self.parts is not None:
            sts = []
            for e in self.parts(params):
                vec, _, _ = flatten_and_pad(e, self.n_shards)
                sts.append(self.inner.init(
                    jnp.zeros((vec.size // self.n_shards,), vec.dtype)))
            return sts
        vec, _, _ = flatten_and_pad(params, self.n_shards)
        chunk = vec.size // self.n_shards
        return self.inner.init(jnp.zeros((chunk,), vec.dtype))

    def apply(self, params, opt_state, grads):
        from repro.core.agent import flatten_and_pad
        if self.n_shards == 1:
            # sharding into one chunk is the identity: delegate to the
            # inner optimizer untouched, so a size-1 shard axis is a
            # bitwise no-op BY CONSTRUCTION (same code path, same
            # pytree-shaped opt_state as the replicated trainer)
            return self.inner.apply(params, opt_state, grads)
        pre = getattr(self.inner, "pre", None)
        bare = (self.inner.shard_update if pre is not None
                else self.inner.update)
        if pre is not None:
            grads = pre(grads)  # full-gradient transform (global norm)
        if self.parts is not None:
            new_entries, new_states = [], []
            for g_t, p_t, st in zip(self.parts(grads),
                                    self.parts(params), opt_state):
                gvec, _, _ = flatten_and_pad(g_t, self.n_shards)
                pvec, size, unravel = flatten_and_pad(p_t, self.n_shards)
                g_loc = local_shard(gvec, self.axis, self.n_shards)
                p_loc = local_shard(pvec, self.axis, self.n_shards)
                upd, st = bare(g_loc, st, p_loc)
                full = all_gather_shards(p_loc + upd, self.axis)
                new_entries.append(unravel(full[:size]))
                new_states.append(st)
            return self.merge(new_entries), new_states
        gvec, _, _ = flatten_and_pad(grads, self.n_shards)
        pvec, size, unravel = flatten_and_pad(params, self.n_shards)
        g_loc = local_shard(gvec, self.axis, self.n_shards)
        p_loc = local_shard(pvec, self.axis, self.n_shards)
        updates, opt_state = bare(g_loc, opt_state, p_loc)
        full = all_gather_shards(p_loc + updates, self.axis)
        return unravel(full[:size]), opt_state


def zero_sharded_optimizer(opt, axis: str, n_shards: int):
    """Wrap `opt` for ZeRO-2 execution over mesh axis `axis` (see
    ZeROShardedOptimizer). The Trainer installs this on the agent's
    optimizer whenever its DistPlan carries a `shard`-role axis."""
    return ZeROShardedOptimizer(opt, axis, n_shards)


class ZeRO3Agent:
    """Full ZeRO-3 discipline over mesh axis `axis`, as an Agent wrapper
    (DistPlan role ``zero3``): the inner agent's optimizer-target params
    (`partition_spec`) are STORED flattened-and-padded 1/n per device in
    TrainState and all-gathered *per use* — gather → compute → drop —
    inside both `learner_step` and `actor_policy`, instead of ZeRO-2's
    persistent replicated copy. The actor-param lag ring is stored as
    chunks too, so per-device params+opt_state+ring bytes all shrink
    toward 1/n.

    The partition is a LIST of entries: one entry when the inner agent
    has no block structure (`partition_list` returns None — the legacy
    whole-vector path, bitwise-unchanged), or one entry per transformer
    superblock + one non-block remainder when it does (layer-wise
    ZeRO-3). Each entry is flattened-and-padded on its own, so the
    trunk's `_run_seq` can gather → run → drop ONE block's params at a
    time and at most one block is ever materialized alongside the
    activations — the whole-vector gather's full-size temps are what
    kept peak LIVE bytes flat at any shard count (BENCH_zero.json
    `zero3_layerwise/peak_live_shrink`).

    Wrapper-form TrainState layout (per device, inside shard_map):

        params    {"zero3": [(chunk_e,) ...] this device's param chunk
                            per partition entry,
                   "rest":  inner params with the partition removed
                            (`replace_partition(params, None)`)}
        ring      [(ring_size, chunk_e) ...] chunked actor-param history
        opt_state untouched (the inner opt is already the ZeRO-2
                  wrapper; layer-wise it is upgraded to the per-entry
                  `parts` mode, so its state is a list of chunk states)

    Every transform is a deterministic concatenation or slice and
    `all_gather_shards ∘ local_shard` is the identity on each padded
    entry vector, so a ZeRO-3 fit is f32-bitwise the replicated fit and
    a size-1 shard axis is a bitwise no-op (pinned, same discipline as
    ZeRO-2, in tests/test_trainer.py). `host_state` reassembles a
    host-layout wrapper state back to the inner agent's replicated tree
    form — checkpoints and ParamStore templates stay plan-independent.

    `init` returns HOST layout: chunked leaves carry a leading
    (n_shards,) dim (params["zero3"] entries (n_shards, chunk_e); ring
    entries (n_shards, ring_size, chunk_e)) which the Trainer lays out
    along the shard mesh axis (`Trainer._lay_out_zero3`)."""

    def __init__(self, inner, axis: str, n_shards: int):
        self.inner = inner
        self.axis = axis
        self.n_shards = n_shards
        self.policy = inner.policy
        self.ring_size = inner.ring_size
        self.opt = inner.opt
        self._listwise = False   # resolved in init()

    # -- layout plumbing ----------------------------------------------
    def _flatten(self, tree):
        from repro.core.agent import flatten_and_pad
        return flatten_and_pad(tree, self.n_shards)

    def _entries(self, part):
        """Partition tree -> list of per-entry pytrees (identity list
        for list-free agents)."""
        if self._listwise:
            return list(self.inner.partition_list(part))
        return [part]

    def _merge(self, entries, materialize=False):
        """Inverse of `_entries`. Lazy by default (the stack stays a
        per-block list for the unrolled trunk loop); `materialize=True`
        restacks into the canonical host/checkpoint layout."""
        if self._listwise:
            return self.inner.merge_partition_list(
                entries, materialize=materialize)
        return entries[0]

    def _gather(self, chunk, e=0):
        """chunk (chunk_e,) -> entry `e`'s pytree (gather-per-use)."""
        vec = all_gather_shards(chunk, self.axis)
        return self._unravels[e](vec[:self._sizes[e]])

    def is_wrapper_state(self, state) -> bool:
        """True for wrapper-form TrainStates (chunked params); False for
        inner/reassembled form (checkpoint restores, fit() output)."""
        return isinstance(state.params, dict) and "zero3" in state.params

    # -- Agent protocol ------------------------------------------------
    def partition_spec(self, state):
        if self.is_wrapper_state(state):
            return state.params["zero3"]
        return self.inner.partition_spec(state)

    def replace_partition(self, params, sub):
        return self.inner.replace_partition(params, sub)

    def partition_list(self, part):
        return self.inner.partition_list(part)

    def merge_partition_list(self, entries, materialize=False):
        return self.inner.merge_partition_list(entries,
                                               materialize=materialize)

    def init(self, key):
        from repro.core.agent import TrainState
        st = self.inner.init(key)
        part = self.inner.partition_spec(st)
        lst = self.inner.partition_list(part)
        self._listwise = lst is not None
        entries = list(lst) if self._listwise else [part]
        if self._listwise and isinstance(self.inner.opt,
                                         ZeROShardedOptimizer):
            # upgrade the ZeRO-2 opt wrapper to per-entry application:
            # opt_state becomes a list of per-entry chunk states,
            # re-seeded here (all-zero moments either way, so the
            # replicate-then-split layout still seeds shards correctly)
            opt = dataclasses.replace(
                self.inner.opt, parts=self.inner.partition_list,
                merge=self.inner.merge_partition_list)
            self.inner.opt = self.opt = opt
            st = TrainState(st.params, opt.init(part), st.extra,
                            st.ring, st.steps)
        self._sizes, self._paddeds = [], []
        self._chunks, self._unravels = [], []
        vecs = []
        for e in entries:
            vec, size, unravel = self._flatten(e)
            vecs.append(vec)
            self._sizes.append(int(size))
            self._paddeds.append(int(vec.size))
            self._chunks.append(int(vec.size) // self.n_shards)
            self._unravels.append(unravel)
        self.n_entries = len(entries)
        # aggregate geometry (reporting, benchmarks, bytes accounting)
        self._size = sum(self._sizes)
        self._padded = sum(self._paddeds)
        self._chunk = sum(self._chunks)
        self._unravel = self._unravels[0]
        slot0 = jax.tree_util.tree_map(lambda r: r[0], st.ring)
        if (jax.tree_util.tree_structure(part)
                != jax.tree_util.tree_structure(slot0)):
            raise ValueError(
                "ZeRO-3 requires the actor ring to store the same pytree "
                "as partition_spec (the behavior params ARE the sharded "
                "partition); got differing structures")
        slot_entries = [self._entries(jax.tree_util.tree_map(
            lambda r: r[d], st.ring)) for d in range(self.ring_size)]
        ring = [jnp.stack([self._flatten(slot_entries[d][e])[0]
                           .reshape(self.n_shards, self._chunks[e])
                           for d in range(self.ring_size)], axis=1)
                for e in range(self.n_entries)]
        params = {"zero3": [v.reshape(self.n_shards, self._chunks[e])
                            for e, v in enumerate(vecs)],
                  "rest": self.inner.replace_partition(st.params, None)}
        return TrainState(params, st.opt_state, st.extra, ring, st.steps)

    def learner_step(self, state, traj, boot_obs, key,
                     grad_tx=None, param_tx=None):
        from repro.core.agent import TrainState
        sub = self._merge([self._gather(c, e) for e, c
                           in enumerate(state.params["zero3"])])
        params = self.inner.replace_partition(state.params["rest"], sub)
        # dummy full ring: the inner step's ring push is discarded (the
        # chunk ring below is authoritative), so XLA DCEs the broadcast
        ring = jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p, (self.ring_size,) + p.shape),
            sub)
        new, metrics = self.inner.learner_step(
            TrainState(params, state.opt_state, state.extra, ring,
                       state.steps),
            traj, boot_obs, key, grad_tx=grad_tx, param_tx=param_tx)
        nchunks = [local_shard(self._flatten(e)[0], self.axis,
                               self.n_shards)
                   for e in self._entries(self.inner.partition_spec(new))]
        ring_c = [jnp.roll(r, 1, axis=0).at[0].set(c)
                  for r, c in zip(state.ring, nchunks)]
        params = {"zero3": nchunks,
                  "rest": self.inner.replace_partition(new.params, None)}
        return (TrainState(params, new.opt_state, new.extra, ring_c,
                           new.steps), metrics)

    def actor_policy(self, state, delay=0):
        from repro.core.agent import TrainState
        if not self.is_wrapper_state(state):
            # reassembled form (fit() output / checkpoint restore, e.g.
            # via ParamStore.publish_from_state) — inner handles it
            return self.inner.actor_policy(state, delay)
        d = jnp.minimum(jnp.asarray(delay, jnp.int32), self.ring_size - 1)
        sub = self._merge([self._gather(jnp.take(r, d, axis=0), e)
                           for e, r in enumerate(state.ring)])
        ring1 = jax.tree_util.tree_map(lambda p: p[None], sub)
        # delay resolved above; inner may still read steps (DQN ε-anneal)
        return self.inner.actor_policy(
            TrainState(None, None, None, ring1, state.steps), 0)

    def host_state(self, state):
        """Reassemble a HOST-layout wrapper TrainState (leading
        (n_shards,) dims on chunked leaves, no mesh dims) into the inner
        agent's replicated tree form, with a template-shaped opt_state —
        `checkpoint.load_train_state` and `ParamStore.publish_from_state`
        route templates through this so they stay plan-independent.
        Inner-form states pass through unchanged."""
        from repro.core.agent import TrainState
        if not self.is_wrapper_state(state):
            return state
        subs = [self._unravels[e](c.reshape(-1)[:self._sizes[e]])
                for e, c in enumerate(state.params["zero3"])]
        sub = self._merge(subs, materialize=True)
        params = self.inner.replace_partition(state.params["rest"], sub)
        slots = []
        for d in range(self.ring_size):
            es = [self._unravels[e](
                state.ring[e][:, d, :].reshape(-1)[:self._sizes[e]])
                for e in range(self.n_entries)]
            slots.append(self._merge(es, materialize=True))
        ring = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *slots)
        opt = getattr(self.inner.opt, "inner", self.inner.opt)
        return TrainState(params, opt.init(sub), state.extra, ring,
                          state.steps)


def strip_worker_dim(tree, n: int = 1):
    """Drop the `n` length-1 leading mesh dims shard_map keeps on leaves
    (one per sharded mesh axis; n=1 is the legacy 1-D worker axis)."""
    axes = tuple(range(n))
    return jax.tree_util.tree_map(lambda a: jnp.squeeze(a, axes), tree)


def restore_worker_dim(tree, n: int = 1):
    """Re-add `n` length-1 leading mesh dims for shard_map outputs."""
    axes = tuple(range(n))
    return jax.tree_util.tree_map(
        lambda a: jnp.expand_dims(a, axes), tree)


def make_distributed_step(loss_fn, optimizer, topology: str, mesh,
                          axis: str = "workers"):
    """Build a jitted multi-worker training step over `mesh[axis]`.

    Worker-local state: (params, opt_state). Batch is sharded over the
    worker axis. allreduce/ps keep replicas bit-identical; gossip lets
    them drift ε-close.
    """
    from jax.experimental.shard_map import shard_map

    def worker_step(params, opt_state, batch):
        # shard_map keeps the (length-1) worker dim — strip and restore
        sq, ex = strip_worker_dim, restore_worker_dim
        params, opt_state, batch = sq(params), sq(opt_state), sq(batch)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = exchange_grads(grads, axis, topology)
        params, opt_state = optimizer.apply(params, opt_state, grads)
        if topology == "gossip":
            params = gossip_mix(params, axis)
        return ex(params), ex(opt_state), jax.lax.pmean(loss, axis)

    # params replicated per-worker => leading worker axis on every leaf
    pspec = P(axis)
    step = shard_map(worker_step, mesh=mesh,
                     in_specs=(pspec, pspec, pspec),
                     out_specs=(pspec, pspec, P()),
                     check_rep=False)
    return jax.jit(step)


def replicate_for(mesh, axis, params):
    """Stack params with leading replica dim(s) — one per mesh axis in
    `axis` (a name or tuple of names, outermost first)."""
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    shape = tuple(mesh.shape[a] for a in names)
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, shape + p.shape), params)
