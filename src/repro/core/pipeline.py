"""Decoupled actor–learner pipeline: the device-resident trajectory
queue (survey §2 learning-system architectures).

Every production architecture the survey describes — Gorila's and
Ape-X's actor/learner separation, SRL's description/execution split —
decouples experience *generation* from *learning* so simulation latency
hides behind the learner update. This module is that seam rendered in
pure SPMD: a fixed-capacity ring of trajectory pytrees plus head/tail
counters, living in the training carry, connecting a rollout *producer*
to a learner *consumer* (repro.core.trainer's ``pipeline=`` mode).

Design points:

  * **Device-resident.** The buffer is an ordinary pytree of jnp
    arrays with a leading ``(capacity,)`` dim per leaf — it rides in
    the superstep carry, is donated along with it (zero-copy, PR 3's
    aliasing machinery applies unchanged), and under a multi-device
    DistPlan each device holds its *own* queue of its local
    trajectories inside ``shard_map`` (no cross-device traffic beyond
    the plan's collectives).

  * **Total functions.** ``queue_push`` on a full queue is a guarded
    no-op returning ``ok=False`` (backpressure: the element is
    *refused*, never silently dropped or overwritten);
    ``queue_pop`` on an empty queue is a guarded no-op returning the
    (stale) head-slot contents and ``ok=False``. The overlap driver's
    static schedule never trips either guard — steady state holds
    exactly ``depth`` items — but the ops stay safe under jit/scan
    where Python-level control flow is unavailable.

  * **Staleness-bounded.** Capacity is the pipeline depth the
    DistPlan's per-axis sync discipline admits
    (``DistPlan.pipeline_depth``): bsp admits none (depth 0 renders as
    lockstep — push-then-pop through one slot, bitwise the fused
    path), ssp admits ``staleness_bound``, asp ``max_delay``. A
    producer can therefore never run further ahead than the sync
    discipline already allowed as policy lag — the queue *realizes*
    structurally the staleness the fused path only models with delay
    schedules.

Counters are monotonically increasing int32 (slot = counter %
capacity), so ``size = tail - head`` needs no emptiness flag and
wraparound is exact until 2**31 pushes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def queue_capacity(q) -> int:
    """Static ring capacity (leading dim of every buffer leaf)."""
    return jax.tree_util.tree_leaves(q["buf"])[0].shape[0]


def queue_size(q):
    """Traced number of items currently queued (0 <= size <= cap)."""
    return q["tail"] - q["head"]


def queue_init(item, capacity: int):
    """Fresh empty queue for items shaped like `item` (arrays or
    ShapeDtypeStructs): every buffer leaf gets a leading ``(capacity,)``
    dim of zeros; head/tail counters start at 0."""
    if capacity < 1:
        raise ValueError(f"queue capacity must be >= 1, got {capacity}")
    buf = jax.tree_util.tree_map(
        lambda s: jnp.zeros((capacity,) + tuple(s.shape), s.dtype), item)
    return {"buf": buf, "head": jnp.zeros((), jnp.int32),
            "tail": jnp.zeros((), jnp.int32)}


def queue_push(q, item):
    """Append `item` at the tail. Full queue => guarded no-op
    (backpressure), returns ``(queue, ok)`` with ``ok=False`` and the
    queue unchanged — an element is never overwritten."""
    cap = queue_capacity(q)
    full = queue_size(q) >= cap
    slot = jax.lax.rem(q["tail"], jnp.int32(cap))

    def write(b, x):
        cur = jax.lax.dynamic_index_in_dim(b, slot, 0, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(
            b, jnp.where(full, cur, x), slot, 0)

    buf = jax.tree_util.tree_map(write, q["buf"], item)
    tail = q["tail"] + jnp.where(full, 0, 1).astype(jnp.int32)
    return {"buf": buf, "head": q["head"], "tail": tail}, ~full


def queue_pop(q):
    """Remove and return the oldest item. Empty queue => guarded no-op:
    returns ``(queue, item, ok)`` with ``ok=False``, the queue
    unchanged, and `item` the stale head-slot contents (well-defined —
    zeros before any push reached that slot)."""
    cap = queue_capacity(q)
    empty = queue_size(q) <= 0
    slot = jax.lax.rem(q["head"], jnp.int32(cap))
    item = jax.tree_util.tree_map(
        lambda b: jax.lax.dynamic_index_in_dim(b, slot, 0, keepdims=False),
        q["buf"])
    head = q["head"] + jnp.where(empty, 0, 1).astype(jnp.int32)
    return {"buf": q["buf"], "head": head, "tail": q["tail"]}, item, ~empty
