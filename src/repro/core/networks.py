"""Policy/value networks: MLP actor-critic + transformer-trunk adapter."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class MLPPolicy:
    """Actor-critic MLP. Discrete: categorical logits; continuous:
    tanh-gaussian (state-independent log-std) squashed into the action
    box `act_mid ± act_scale` — construct with `for_spec` so the bounds
    come from the env's EnvSpec instead of being hard-coded."""

    def __init__(self, obs_dim, n_actions=0, act_dim=1, hidden=(64, 64),
                 act_mid=0.0, act_scale=1.0):
        self.obs_dim = obs_dim
        self.n_actions = n_actions
        self.act_dim = act_dim
        self.hidden = hidden
        self.discrete = n_actions > 0
        self.act_mid = act_mid
        self.act_scale = act_scale

    @classmethod
    def for_spec(cls, spec, hidden=(64, 64)):
        """Build a policy matching an EnvSpec (repro.envs.spec): output
        head width and continuous action bounds read off the spec."""
        a = spec.action
        if a.discrete:
            return cls(spec.obs_dim, a.n, hidden=hidden)
        return cls(spec.obs_dim, 0, a.size, hidden=hidden,
                   act_mid=a.midpoint, act_scale=a.half_range)

    def init(self, key):
        sizes = (self.obs_dim,) + self.hidden
        ks = jax.random.split(key, len(sizes) + 2)
        p = {"layers": [
            {"w": dense_init(ks[i], (sizes[i], sizes[i + 1])),
             "b": jnp.zeros((sizes[i + 1],))}
            for i in range(len(sizes) - 1)]}
        out = self.n_actions if self.discrete else self.act_dim
        p["pi"] = {"w": dense_init(ks[-2], (sizes[-1], out), scale=0.01),
                   "b": jnp.zeros((out,))}
        p["v"] = {"w": dense_init(ks[-1], (sizes[-1], 1), scale=1.0),
                  "b": jnp.zeros((1,))}
        if not self.discrete:
            p["log_std"] = jnp.full((self.act_dim,), -0.5)
        return p

    def trunk(self, params, obs):
        h = obs
        for lay in params["layers"]:
            h = jnp.tanh(h @ lay["w"] + lay["b"])
        return h

    def apply(self, params, obs):
        """-> (pi_out, value). pi_out: logits (discrete) or mean."""
        h = self.trunk(params, obs)
        pi = h @ params["pi"]["w"] + params["pi"]["b"]
        v = (h @ params["v"]["w"] + params["v"]["b"])[..., 0]
        return pi, v

    # -- distributions -------------------------------------------------
    def _dist_sample(self, params, pi, key):
        """Draw (action, log_prob) from the head output `pi`."""
        if self.discrete:
            a = jax.random.categorical(key, pi)
            logp = jax.nn.log_softmax(pi)[
                ..., a] if pi.ndim == 1 else jnp.take_along_axis(
                jax.nn.log_softmax(pi), a[..., None], -1)[..., 0]
            return a, logp
        std = jnp.exp(params["log_std"])
        eps = jax.random.normal(key, pi.shape)
        a = pi + std * eps
        logp = (-0.5 * ((a - pi) / std) ** 2
                - jnp.log(std) - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)
        return jnp.tanh(a) * self.act_scale + self.act_mid, logp

    def sample(self, params, obs, key):
        """-> (action, log_prob)."""
        pi, _ = self.apply(params, obs)
        return self._dist_sample(params, pi, key)

    def sample_value(self, params, obs, key):
        """-> (action, log_prob, value) from ONE forward pass — the
        rollout engine's hot path (rollout.py runs one trunk evaluation
        per env step instead of sample + apply)."""
        pi, v = self.apply(params, obs)
        a, logp = self._dist_sample(params, pi, key)
        return a, logp, v

    def log_prob(self, params, obs, action):
        pi, v = self.apply(params, obs)
        if self.discrete:
            lp = jnp.take_along_axis(jax.nn.log_softmax(pi),
                                     action[..., None].astype(jnp.int32),
                                     -1)[..., 0]
            ent = -jnp.sum(jax.nn.softmax(pi) * jax.nn.log_softmax(pi), -1)
            return lp, v, ent
        # invert the tanh squashing into the action box
        raw = jnp.arctanh(jnp.clip((action - self.act_mid)
                                   / self.act_scale, -0.999, 0.999))
        std = jnp.exp(params["log_std"])
        lp = (-0.5 * ((raw - pi) / std) ** 2
              - jnp.log(std) - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)
        ent = (0.5 + 0.5 * jnp.log(2 * jnp.pi) +
               jnp.log(std)).sum() * jnp.ones_like(v)
        return lp, v, ent


class TrunkPolicy:
    """Any registry architecture as a policy trunk (survey §2 LLM-actor
    mapping): integer token observation -> transformer -> policy/value
    heads. Used by examples/ppo_trunk_gridworld.py."""

    def __init__(self, arch="paper-drl-trunk", n_actions=4, ctx=8,
                 reduced=True):
        from repro.models import build_model
        from repro.models.model import ModelOpts
        self.lm = build_model(arch, ModelOpts(dtype="float32", remat=False),
                              reduced=reduced)
        self.n_actions = n_actions
        self.ctx = ctx
        self.discrete = True
        self.obs_dim = ctx

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        d = self.lm.cfg.d_model
        return {"lm": self.lm.init(k1),
                "pi": {"w": dense_init(k2, (d, self.n_actions),
                                       scale=0.01),
                       "b": jnp.zeros((self.n_actions,))},
                "v": {"w": dense_init(k3, (d, 1)), "b": jnp.zeros((1,))}}

    def apply(self, params, tokens):
        """tokens: (..., ctx) int32 history of token observations."""
        tok = tokens.astype(jnp.int32) % self.lm.cfg.vocab
        squeeze = tok.ndim == 1
        if squeeze:
            tok = tok[None]
        from repro.models.layers import (embed_tokens, apply_norm)
        x = embed_tokens(params["lm"]["embed"], tok, self.lm.cfg,
                         jnp.float32)
        x, _, _ = self.lm._run_seq(params["lm"], x, jnp.int32(0), None, 0)
        h = apply_norm(params["lm"]["final_norm"], x)[:, -1]
        pi = h @ params["pi"]["w"] + params["pi"]["b"]
        v = (h @ params["v"]["w"] + params["v"]["b"])[..., 0]
        if squeeze:
            pi, v = pi[0], v[0]
        return pi, v

    _dist_sample = MLPPolicy._dist_sample
    sample = MLPPolicy.sample
    sample_value = MLPPolicy.sample_value
    log_prob = MLPPolicy.log_prob
