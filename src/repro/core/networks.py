"""Policy/value networks: MLP actor-critic + transformer-trunk adapter."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


class MLPPolicy:
    """Actor-critic MLP. Discrete: categorical logits; continuous:
    tanh-gaussian (state-independent log-std) squashed into the action
    box `act_mid ± act_scale` — construct with `for_spec` so the bounds
    come from the env's EnvSpec instead of being hard-coded."""

    def __init__(self, obs_dim, n_actions=0, act_dim=1, hidden=(64, 64),
                 act_mid=0.0, act_scale=1.0):
        self.obs_dim = obs_dim
        self.n_actions = n_actions
        self.act_dim = act_dim
        self.hidden = hidden
        self.discrete = n_actions > 0
        self.act_mid = act_mid
        self.act_scale = act_scale

    @classmethod
    def for_spec(cls, spec, hidden=(64, 64)):
        """Build a policy matching an EnvSpec (repro.envs.spec): output
        head width and continuous action bounds read off the spec."""
        a = spec.action
        if a.discrete:
            return cls(spec.obs_dim, a.n, hidden=hidden)
        return cls(spec.obs_dim, 0, a.size, hidden=hidden,
                   act_mid=a.midpoint, act_scale=a.half_range)

    def init(self, key):
        sizes = (self.obs_dim,) + self.hidden
        ks = jax.random.split(key, len(sizes) + 2)
        p = {"layers": [
            {"w": dense_init(ks[i], (sizes[i], sizes[i + 1])),
             "b": jnp.zeros((sizes[i + 1],))}
            for i in range(len(sizes) - 1)]}
        out = self.n_actions if self.discrete else self.act_dim
        p["pi"] = {"w": dense_init(ks[-2], (sizes[-1], out), scale=0.01),
                   "b": jnp.zeros((out,))}
        p["v"] = {"w": dense_init(ks[-1], (sizes[-1], 1), scale=1.0),
                  "b": jnp.zeros((1,))}
        if not self.discrete:
            p["log_std"] = jnp.full((self.act_dim,), -0.5)
        return p

    def trunk(self, params, obs):
        h = obs
        for lay in params["layers"]:
            h = jnp.tanh(h @ lay["w"] + lay["b"])
        return h

    def apply(self, params, obs):
        """-> (pi_out, value). pi_out: logits (discrete) or mean."""
        h = self.trunk(params, obs)
        pi = h @ params["pi"]["w"] + params["pi"]["b"]
        v = (h @ params["v"]["w"] + params["v"]["b"])[..., 0]
        return pi, v

    # -- distributions -------------------------------------------------
    def _dist_sample(self, params, pi, key):
        """Draw (action, log_prob) from the head output `pi`."""
        if self.discrete:
            a = jax.random.categorical(key, pi)
            logp = jax.nn.log_softmax(pi)[
                ..., a] if pi.ndim == 1 else jnp.take_along_axis(
                jax.nn.log_softmax(pi), a[..., None], -1)[..., 0]
            return a, logp
        std = jnp.exp(params["log_std"])
        eps = jax.random.normal(key, pi.shape)
        a = pi + std * eps
        logp = (-0.5 * ((a - pi) / std) ** 2
                - jnp.log(std) - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)
        return jnp.tanh(a) * self.act_scale + self.act_mid, logp

    def sample(self, params, obs, key):
        """-> (action, log_prob)."""
        pi, _ = self.apply(params, obs)
        return self._dist_sample(params, pi, key)

    def sample_value(self, params, obs, key):
        """-> (action, log_prob, value) from ONE forward pass — the
        rollout engine's hot path (rollout.py runs one trunk evaluation
        per env step instead of sample + apply)."""
        pi, v = self.apply(params, obs)
        a, logp = self._dist_sample(params, pi, key)
        return a, logp, v

    def log_prob(self, params, obs, action):
        pi, v = self.apply(params, obs)
        if self.discrete:
            lp = jnp.take_along_axis(jax.nn.log_softmax(pi),
                                     action[..., None].astype(jnp.int32),
                                     -1)[..., 0]
            ent = -jnp.sum(jax.nn.softmax(pi) * jax.nn.log_softmax(pi), -1)
            return lp, v, ent
        # invert the tanh squashing into the action box
        raw = jnp.arctanh(jnp.clip((action - self.act_mid)
                                   / self.act_scale, -0.999, 0.999))
        std = jnp.exp(params["log_std"])
        lp = (-0.5 * ((raw - pi) / std) ** 2
              - jnp.log(std) - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)
        ent = (0.5 + 0.5 * jnp.log(2 * jnp.pi) +
               jnp.log(std)).sum() * jnp.ones_like(v)
        return lp, v, ent


class TrunkPolicy:
    """Any registry architecture as a policy trunk (survey §2 LLM-actor
    mapping): observation -> transformer -> policy/value heads, with
    attention routed through `repro.core.attention` (the flash-attention
    dispatcher) when `use_kernels` is on.

    Two observation modes, chosen by `for_spec` off the EnvSpec:
      * token mode (integer obs, `obs_dim=None`): the (..., ctx) int
        history embeds through the model's token table — the original
        adapter (examples/ppo_trunk_gridworld.py).
      * feature mode (float obs, `obs_dim=F`): each scalar feature
        becomes one sequence position via a learned per-feature affine
        lift `obs[..., i] * w[i] + b[i]` into d_model, bypassing the
        token table — so box-observation envs (cartpole, pendulum)
        train the same transformer trunk.
    Discrete heads emit logits; continuous heads reuse MLPPolicy's
    tanh-gaussian squashed into `act_mid ± act_scale`."""

    def __init__(self, arch="paper-drl-trunk", n_actions=4, ctx=8,
                 reduced=True, obs_dim=None, act_dim=1, act_mid=0.0,
                 act_scale=1.0, use_kernels=False):
        from repro.models import build_model
        from repro.models.model import ModelOpts
        self.lm = build_model(arch, ModelOpts(dtype="float32", remat=False,
                                              use_kernels=use_kernels),
                              reduced=reduced)
        self.n_actions = n_actions
        self.discrete = n_actions > 0
        self.features = obs_dim          # None => token-obs mode
        self.ctx = ctx if obs_dim is None else obs_dim
        self.obs_dim = self.ctx
        self.act_dim = act_dim
        self.act_mid = act_mid
        self.act_scale = act_scale

    @classmethod
    def for_spec(cls, spec, arch="paper-drl-trunk", reduced=True,
                 use_kernels=True):
        """Build a trunk policy matching an EnvSpec: integer obs run in
        token mode, float obs in feature mode; head width and continuous
        action bounds read off the spec (mirrors MLPPolicy.for_spec)."""
        a, o = spec.action, spec.observation
        kw = dict(arch=arch, reduced=reduced, use_kernels=use_kernels)
        if jnp.issubdtype(jnp.dtype(o.dtype), jnp.integer):
            kw["ctx"] = spec.obs_dim
        else:
            kw["obs_dim"] = spec.obs_dim
        if a.discrete:
            return cls(n_actions=a.n, **kw)
        return cls(n_actions=0, act_dim=a.size, act_mid=a.midpoint,
                   act_scale=a.half_range, **kw)

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        d = self.lm.cfg.d_model
        out = self.n_actions if self.discrete else self.act_dim
        p = {"lm": self.lm.init(k1),
             "pi": {"w": dense_init(k2, (d, out), scale=0.01),
                    "b": jnp.zeros((out,))},
             "v": {"w": dense_init(k3, (d, 1)), "b": jnp.zeros((1,))}}
        if self.features is not None:
            p["feat"] = {"w": dense_init(k4, (self.features, d)),
                         "b": jnp.zeros((self.features, d))}
        if not self.discrete:
            p["log_std"] = jnp.full((self.act_dim,), -0.5)
        return p

    def apply(self, params, obs):
        """obs: (..., ctx) int token history or (..., F) float features
        -> (pi_out, value); pi_out logits (discrete) or mean."""
        squeeze = obs.ndim == 1
        if squeeze:
            obs = obs[None]
        from repro.models.layers import (embed_tokens, apply_norm)
        if self.features is None:
            tok = obs.astype(jnp.int32) % self.lm.cfg.vocab
            x = embed_tokens(params["lm"]["embed"], tok, self.lm.cfg,
                             jnp.float32)
        else:
            x = (obs.astype(jnp.float32)[..., None]
                 * params["feat"]["w"] + params["feat"]["b"])  # (B, F, d)
        x, _, _ = self.lm._run_seq(params["lm"], x, jnp.int32(0), None, 0)
        h = apply_norm(params["lm"]["final_norm"], x)[:, -1]
        pi = h @ params["pi"]["w"] + params["pi"]["b"]
        v = (h @ params["v"]["w"] + params["v"]["b"])[..., 0]
        if squeeze:
            pi, v = pi[0], v[0]
        return pi, v

    # -- layer-wise ZeRO-3 partition hooks -----------------------------
    def partition_list(self, params):
        """Split a policy-params pytree into per-block ZeRO-3 entries:
        one per superblock of the scan stack + the non-block remainder
        (embed, final_norm, heads, feat, log_std). Accepts both the
        canonical stacked stack (leading (repeats,) dim) and the lazy
        list form a previous merge produced. Returns None when the
        trunk has no scan stack (repeats == 0) — the caller then uses
        the single-partition path."""
        lm = params.get("lm") if isinstance(params, dict) else None
        if not isinstance(lm, dict) or lm.get("stack") is None:
            return None
        stack = lm["stack"]
        if isinstance(stack, (list, tuple)):
            blocks = list(stack)
        else:
            blocks = [jax.tree_util.tree_map(lambda a: a[r], stack)
                      for r in range(self.lm.repeats)]
        rest = dict(params, lm=dict(lm, stack=None))
        return blocks + [rest]

    def merge_partition_list(self, entries, materialize=False):
        """Inverse of `partition_list`. `materialize=False` keeps the
        stack as a list of per-block pytrees — `_run_seq` then runs the
        blocks unrolled, so each block's all-gather is consumed and
        dropped before the next one materializes; `materialize=True`
        restacks into the canonical (repeats, ...) layout used by
        host/checkpoint forms."""
        blocks, rest = list(entries[:-1]), entries[-1]
        if materialize:
            stack = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *blocks)
        else:
            stack = blocks
        return dict(rest, lm=dict(rest["lm"], stack=stack))

    _dist_sample = MLPPolicy._dist_sample
    sample = MLPPolicy.sample
    sample_value = MLPPolicy.sample_value
    log_prob = MLPPolicy.log_prob


def make_policy(spec, policy="mlp", hidden=(64, 64), **trunk_kwargs):
    """Policy factory shared by the algorithm registry: `policy="mlp"`
    (the house actor-critic MLP, `hidden` widths) or `policy="trunk"`
    (the transformer trunk via TrunkPolicy.for_spec; `trunk_kwargs`
    forwards arch/reduced/use_kernels)."""
    if policy == "trunk":
        return TrunkPolicy.for_spec(spec, **trunk_kwargs)
    if policy != "mlp":
        raise ValueError(f"unknown policy {policy!r}: expected 'mlp' "
                         f"or 'trunk'")
    return MLPPolicy.for_spec(spec, hidden)
