"""Distributed synchronization mechanisms (survey §6, Fig. 6):
BSP / ASP / SSP as a *deterministic staleness engine*.

SPMD adaptation (DESIGN.md §4.3): true asynchrony has no reproducible
JAX analogue, but what the survey says matters — *stale updates* (workers
computing gradients against old params) — is modeled exactly: a history
buffer of the last D+1 param versions is carried through lax.scan and
worker w at step t reads version `delay[t, w]`:

    BSP: delay ≡ 0 (bulk-synchronous, consistent)
    ASP: delay ~ U[0, max_delay]       (unbounded staleness)
    SSP: delay ~ min(U[0, max_delay], bound)  (stale-synchronous)

benchmarks/fig6_sync.py reproduces the survey's qualitative claim:
ASP degrades convergence vs BSP; SSP recovers most of it at a fraction
of the synchronization cost.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

MECHANISMS = ("bsp", "asp", "ssp")


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    mechanism: str = "bsp"        # bsp | asp | ssp
    n_workers: int = 4
    max_delay: int = 4            # ASP worst case
    staleness_bound: int = 1      # SSP bound


def pipeline_depth(cfg: SyncConfig) -> int:
    """How far a decoupled rollout producer may run AHEAD of the
    learner consumer under this sync discipline — the trajectory-queue
    depth of the Trainer's ``pipeline=`` mode (repro.core.pipeline).

    The mapping is the same staleness budget `make_delays` spends as
    random policy-lag: BSP admits none (depth 0 = lockstep, bitwise the
    fused path), SSP admits its bound, ASP its worst case. The fused
    path *models* that staleness by reading lagged params out of the
    actor ring; the pipelined path *realizes* it — a trajectory
    consumed at iteration t was produced `depth` iterations earlier
    with the params then newest, so the actor-param lag is structural
    (exactly `depth`), not sampled."""
    if cfg.mechanism == "bsp":
        return 0
    if cfg.mechanism == "asp":
        return cfg.max_delay
    if cfg.mechanism == "ssp":
        return min(cfg.max_delay, cfg.staleness_bound)
    raise ValueError(cfg.mechanism)


def make_delays(cfg: SyncConfig, n_steps: int, key):
    if cfg.mechanism == "bsp":
        return jnp.zeros((n_steps, cfg.n_workers), jnp.int32)
    d = jax.random.randint(key, (n_steps, cfg.n_workers), 0,
                           cfg.max_delay + 1)
    if cfg.mechanism == "ssp":
        d = jnp.minimum(d, cfg.staleness_bound)
    elif cfg.mechanism != "asp":
        raise ValueError(cfg.mechanism)
    return d


def train_with_staleness(loss_fn, params0, optimizer, batches, delays):
    """Run data-parallel training under a staleness schedule.

    loss_fn(params, batch) -> scalar;
    batches: pytree with leading dims (T, W, ...);
    delays:  (T, W) int32, delay d => grads from params d steps old.
    Returns (final params, losses (T,))."""
    D = int(jax.device_get(delays.max())) if delays.size else 0
    hist0 = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (D + 1,) + p.shape), params0)
    opt_state0 = optimizer.init(params0)

    def step(carry, xs):
        params, opt_state, hist = carry
        batch_w, delay_w = xs

        def worker(b, d):
            stale = jax.tree_util.tree_map(
                lambda h: jnp.take(h, jnp.minimum(d, D), axis=0), hist)
            return jax.value_and_grad(loss_fn)(stale, b)

        losses, grads = jax.vmap(worker)(batch_w, delay_w)
        g = jax.tree_util.tree_map(lambda x: x.mean(0), grads)
        params, opt_state = optimizer.apply(params, opt_state, g)
        hist = jax.tree_util.tree_map(
            lambda h, p: jnp.roll(h, 1, axis=0).at[0].set(p), hist, params)
        return (params, opt_state, hist), losses.mean()

    (params, _, _), losses = jax.lax.scan(
        step, (params0, opt_state0, hist0), (batches, delays))
    return params, losses


def sync_cost_model(cfg: SyncConfig, t_compute_mean, t_compute_std,
                    n_steps, key):
    """Analytic throughput model (survey §6.2 synchronization barrier):
    per-step wall time under worker-speed heterogeneity ~N(mean, std).
    BSP waits for the max; ASP takes the mean; SSP waits only when the
    bound trips (approximated as a max over a `bound`-step window)."""
    t = jnp.maximum(t_compute_mean + t_compute_std * jax.random.normal(
        key, (n_steps, cfg.n_workers)), 1e-3)
    if cfg.mechanism == "bsp":
        return t.max(axis=1).sum()
    if cfg.mechanism == "asp":
        return t.mean(axis=1).sum()
    # ssp: amortized barrier every `bound` steps
    b = max(cfg.staleness_bound, 1)
    pad = (-n_steps) % b
    tw = jnp.pad(t, ((0, pad), (0, 0))).reshape(-1, b, cfg.n_workers)
    # per b-step window: (b-1) free-running steps + one barrier step
    return (tw.mean(axis=(1, 2)) * (b - 1) + tw.max(axis=(1, 2))).sum()
