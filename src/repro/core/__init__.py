"""repro.core — the survey's taxonomy as a composable framework.

Axes (each independently selectable through the unified Trainer):
  collective: ps | allreduce | gossip       (survey §3, per mesh axis)
  sync:       bsp | asp | ssp               (survey §6, per mesh axis)
  algo:       dqn | ppo | impala | a3c      (unified Agent registry)
  evo:        es | ga | erl                 (survey §7, evolution training)

All backprop algorithms train through one seam: `agent.make(name, env)`
builds an Agent (init / actor_policy / learner_step over a TrainState
pytree) and `trainer.Trainer` drives it under a declarative
`distribution.DistPlan` — fused supersteps, hierarchical shard_map
meshes (e.g. hosts x workers), per-axis collective-routed gradients,
per-axis sync-scheduled policy lag, elastic actor shards.
"""
from repro.core.networks import MLPPolicy  # noqa: F401
from repro.core.rollout import rollout  # noqa: F401
from repro.core.vtrace import vtrace  # noqa: F401
from repro.envs.api import Env  # noqa: F401
from repro.envs.cartpole import CartPole  # noqa: F401
from repro.envs.pendulum import Pendulum  # noqa: F401
from repro.envs.gridworld import GridWorld  # noqa: F401
from repro.core.agent import Agent, TrainState  # noqa: F401
from repro.core.distribution import AxisSpec, DistPlan  # noqa: F401
from repro.core.trainer import Trainer, TrainerConfig  # noqa: F401
from repro.core.serving import (ParamStore, RequestBatcher,  # noqa: F401
                                ServeEngine, bucket_for)
