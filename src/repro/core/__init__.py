"""repro.core — the survey's taxonomy as a composable framework.

Axes (each independently selectable):
  topology:  ps | allreduce | gossip        (survey §3)
  sync:      bsp | asp | ssp                (survey §6)
  algo:      dqn | ppo | impala | a3c       (backprop training)
  evo:       es | ga | erl                  (survey §7, evolution training)
"""
from repro.core.networks import MLPPolicy  # noqa: F401
from repro.core.rollout import rollout  # noqa: F401
from repro.core.vtrace import vtrace  # noqa: F401
