"""Distribution Plan API — the declarative description of how training
is spread over devices (survey §3 architectures x §6 synchronization,
composed hierarchically).

Real DRL systems compose *hierarchies* of parallelism — intra-node
allreduce under inter-node parameter-server or gossip, with per-level
sync disciplines (SRL separates the dataflow description from its
execution; ElegantRL-Podracer makes actor counts a scheduling knob).
A `DistPlan` is that description as a static pytree-of-config:

  * a device mesh of named axes (`AxisSpec`), outermost first —
    default 1-D ``(workers,)``, first-class 2-D ``(hosts, workers)``;
  * a per-axis collective — ``allreduce`` / ``ps`` / ``gossip`` —
    compiled into the Trainer's `grad_tx`/`param_tx` hooks. Consecutive
    allreduce axes fuse into ONE collective over the axis-name tuple,
    so a (1, N) or (2, N/2) nesting of pure allreduce lowers to the
    same all-reduce over the same device group as the flat plan and
    stays bitwise-identical (pinned in tests/test_trainer.py);
  * a per-axis sync schedule — ``bsp``/``asp``/``ssp`` rendered as
    policy-lag delays (repro.core.sync) which ADD across levels: a
    device at mesh coordinates (i0, i1, ...) acts with params
    ``sum_a delay_a[t, i_a]`` learner-updates old;
  * a per-axis ``role`` — ``data`` (plain data-parallel workers),
    ``shard`` (ZeRO-2 learner-state sharding, §5 memory ceiling): over
    a shard axis the Trainer reduce-scatters gradients, applies the
    optimizer update on the local 1/N slice of the flattened
    params/opt_state, and all-gathers params before the next rollout.
    A shard axis must use ``allreduce`` (its gradient mean fuses into
    the data-parallel pmean, making pmean + local slice the
    reduce-scatter), so a sharded plan trains f32-bitwise-identically
    to its replicated counterpart and a shard axis of size 1 is a
    bitwise no-op (pinned in tests/test_trainer.py);
    ``zero3`` (full ZeRO-3: params additionally stored as 1/N chunks,
    gathered per use); or ``replay`` (sharded replay service, Gorila's
    distributed replay memory): the replay group holds ONE logical
    replay buffer, each member owning a contiguous 1/N capacity slice.
    Members replicate the data-position rollout/learner compute (the
    axis adds replay capacity, not sample throughput), insertion
    routes transitions to the owning shard, sampling merges per-shard
    Gumbel-top-k candidates over the axis, and priority write-back
    routes to the owner — draw-for-draw the single-buffer
    PrioritizedReplay, so the fit stays bitwise the flat data plan
    (pinned in tests/test_replay_service.py);
  * an optional elastic ``actors=`` schedule: total env-shard counts
    cycled per superstep dispatch. Agents only consume ``traj``, so
    resharding between supersteps is invisible to them.

The legacy single-axis path (`n_workers`/`topology`/`sync` flags) lowers
onto `DistPlan.flat(...)` and stays bitwise-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sync import (MECHANISMS, SyncConfig, make_delays,
                             pipeline_depth as _sync_pipeline_depth)
from repro.core.topology import TOPOLOGIES, exchange_grads, gossip_mix

_SYNC_EXTRA = {"bsp": lambda ax: 0,
               "asp": lambda ax: ax.max_delay,
               "ssp": lambda ax: min(ax.max_delay, ax.staleness_bound)}

ROLES = ("data", "shard", "zero3", "replay")


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """One named mesh axis: its size, how gradients/params are exchanged
    across it (§3), how stale its members may act (§6), and its role —
    `data` (plain data-parallel workers), `shard` (ZeRO-2 learner-
    state sharding: gradients are reduce-scattered over the axis, the
    optimizer update runs on the local 1/size slice of the flattened
    params/opt_state, and params are all-gathered before the next
    rollout), `zero3` (full ZeRO-3: params are additionally STORED
    as 1/size chunks in TrainState and all-gathered per use inside
    learner_step/actor_policy — gather, compute, drop), or `replay`
    (sharded replay service: the group holds ONE logical replay buffer,
    1/size of its capacity per member, while replicating the
    data-position compute)."""
    name: str
    size: int
    collective: str = "allreduce"   # §3: allreduce | ps | gossip
    sync: str = "bsp"               # §6: bsp | asp | ssp
    max_delay: int = 4              # asp worst-case extra staleness
    staleness_bound: int = 1        # ssp bound on extra staleness
    role: str = "data"              # data | shard | zero3 | replay

    def __post_init__(self):
        if not self.name:
            raise ValueError("axis name must be non-empty")
        if self.size < 1:
            raise ValueError(f"axis {self.name!r}: size {self.size} < 1")
        if self.collective not in TOPOLOGIES:
            raise ValueError(f"axis {self.name!r}: collective "
                             f"{self.collective!r} not in {TOPOLOGIES}")
        if self.sync not in MECHANISMS:
            raise ValueError(f"axis {self.name!r}: sync {self.sync!r} "
                             f"not in {MECHANISMS}")
        if self.role not in ROLES:
            raise ValueError(f"axis {self.name!r}: role {self.role!r} "
                             f"not in {ROLES}")
        if self.role in ("shard", "zero3") and self.collective != "allreduce":
            raise ValueError(
                f"axis {self.name!r}: a {self.role}-role axis must use "
                f"the 'allreduce' collective (got {self.collective!r}) — "
                f"its gradient mean fuses into the data-parallel "
                f"reduction so that pmean + local slice IS the "
                f"reduce-scatter (bitwise the replicated plan)")
        if self.role == "replay" and self.collective != "allreduce":
            raise ValueError(
                f"axis {self.name!r}: a replay-role axis must use the "
                f"'allreduce' collective (got {self.collective!r}) — "
                f"the sharded replay service merges per-shard top-k "
                f"candidates and assembles batches with all-gather/psum "
                f"over the axis, which presumes the synchronous "
                f"allreduce domain")
        if self.role == "zero3" and self.sync != "bsp":
            raise ValueError(
                f"axis {self.name!r}: a zero3-role axis must use 'bsp' "
                f"sync (got {self.sync!r}) — the gather-per-use params "
                f"are assembled from one ring slot per shard member, so "
                f"shard-group members must act in lockstep; spend the "
                f"staleness budget on the data axes instead")
        if self.role == "replay" and self.sync != "bsp":
            raise ValueError(
                f"axis {self.name!r}: a replay-role axis must use 'bsp' "
                f"sync (got {self.sync!r}) — replay-group members hold "
                f"slices of ONE logical buffer, so they must act in "
                f"lockstep for its contents to stay coherent; spend the "
                f"staleness budget on the data axes instead")

    @property
    def ring_extra(self) -> int:
        """Actor-ring depth this axis's sync discipline can reach into."""
        return _SYNC_EXTRA[self.sync](self)

    @property
    def pipeline_depth(self) -> int:
        """Trajectory-queue depth this axis's sync discipline admits in
        the Trainer's ``pipeline=`` mode (repro.core.sync.pipeline_depth):
        bsp -> 0 (lockstep), ssp -> staleness_bound, asp -> max_delay.
        Numerically the same staleness budget as `ring_extra` — the
        fused path spends it as sampled policy lag, the pipelined path
        as producer run-ahead."""
        return _sync_pipeline_depth(SyncConfig(
            self.sync, self.size, self.max_delay, self.staleness_bound))


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Hierarchical distribution plan: mesh axes (outermost first) plus
    an optional elastic actor-shard schedule. Static / hashable — safe
    to close over in jitted code."""
    axes: Tuple[AxisSpec, ...] = (AxisSpec("workers", 1),)
    actors: Optional[Tuple[int, ...]] = None  # env shards per superstep

    def __post_init__(self):
        if not self.axes:
            raise ValueError("DistPlan needs at least one mesh axis "
                             "(empty axis list)")
        names = [a.name for a in self.axes]
        if len(set(names)) != len(names):
            dups = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate mesh axis name(s) {dups} "
                             f"in {names}")
        shards = [a.name for a in self.axes if a.role in ("shard", "zero3")]
        if len(shards) > 1:
            raise ValueError(f"at most one shard-role axis is supported "
                             f"(got {shards}); compose a bigger shard "
                             f"group as one axis instead")
        replays = [a.name for a in self.axes if a.role == "replay"]
        if len(replays) > 1:
            raise ValueError(f"at most one replay-role axis is supported "
                             f"(got {replays}); compose a bigger replay "
                             f"group as one axis instead")
        if self.actors is not None:
            if not self.actors:
                raise ValueError("actors= schedule must be non-empty")
            bad = [n for n in self.actors if n < 1]
            if bad:
                raise ValueError(f"actors= entries must be >= 1: {bad}")
            object.__setattr__(self, "actors", tuple(self.actors))
        object.__setattr__(self, "axes", tuple(self.axes))

    # ---- constructors -------------------------------------------------
    @classmethod
    def flat(cls, n_workers: int = 1, collective: str = "allreduce",
             sync: str = "bsp", max_delay: int = 4,
             staleness_bound: int = 1, actors=None,
             axis: str = "workers") -> "DistPlan":
        """The legacy single-axis path as a plan: 1-D (workers,) mesh.
        `Trainer(env, TrainerConfig(plan=DistPlan.flat(4)))` is bitwise
        what `n_workers=4, topology="allreduce", sync="bsp"` was."""
        return cls(axes=(AxisSpec(axis, n_workers, collective, sync,
                                  max_delay, staleness_bound),),
                   actors=None if actors is None else tuple(actors))

    @classmethod
    def grid(cls, hosts: int, workers: int,
             inter: str = "allreduce", intra: str = "allreduce",
             inter_sync: str = "bsp", intra_sync: str = "bsp",
             max_delay: int = 4, staleness_bound: int = 1,
             actors=None) -> "DistPlan":
        """First-class 2-D (hosts, workers) plan: `intra` is the
        collective/sync within a host (the inner axis), `inter` across
        hosts (the outer axis) — e.g. intra-host allreduce + inter-host
        gossip."""
        return cls(axes=(AxisSpec("hosts", hosts, inter, inter_sync,
                                  max_delay, staleness_bound),
                         AxisSpec("workers", workers, intra, intra_sync,
                                  max_delay, staleness_bound)),
                   actors=None if actors is None else tuple(actors))

    @classmethod
    def zero(cls, n_workers: int, n_shards: int,
             collective: str = "allreduce", sync: str = "bsp",
             max_delay: int = 4, staleness_bound: int = 1,
             actors=None) -> "DistPlan":
        """Data-parallel workers + a ZeRO-2 shard axis (innermost, so
        the shard group sits on the fastest fabric): gradients reduce-
        scatter over `shard`, the optimizer updates the local 1/n slice,
        params all-gather before the next rollout."""
        return cls(axes=(AxisSpec("workers", n_workers, collective, sync,
                                  max_delay, staleness_bound),
                         AxisSpec("shard", n_shards, "allreduce", "bsp",
                                  max_delay, staleness_bound,
                                  role="shard")),
                   actors=None if actors is None else tuple(actors))

    @classmethod
    def zero3(cls, n_workers: int, n_shards: int,
              collective: str = "allreduce", sync: str = "bsp",
              max_delay: int = 4, staleness_bound: int = 1,
              actors=None) -> "DistPlan":
        """Data-parallel workers + a full ZeRO-3 shard axis (innermost):
        like `zero()` but params are also stored as 1/n chunks and all-
        gathered per use inside learner_step/actor_policy — gather,
        compute, drop — so per-device params+opt_state bytes shrink
        toward 1/n instead of only the opt_state."""
        return cls(axes=(AxisSpec("workers", n_workers, collective, sync,
                                  max_delay, staleness_bound),
                         AxisSpec("shard", n_shards, "allreduce", "bsp",
                                  max_delay, staleness_bound,
                                  role="zero3")),
                   actors=None if actors is None else tuple(actors))

    @classmethod
    def replay(cls, n_workers: int, n_shards: int,
               collective: str = "allreduce", sync: str = "bsp",
               max_delay: int = 4, staleness_bound: int = 1,
               actors=None) -> "DistPlan":
        """Data-parallel workers + a sharded-replay axis (innermost):
        the replay group holds ONE logical replay buffer, each member
        owning a contiguous 1/n slice of its capacity (Gorila's
        distributed replay memory as collectives over the mesh).
        Members replicate the data-axis rollout/learner compute — the
        axis adds replay capacity, not sample throughput — so the fit
        is bitwise the flat `n_workers` plan (tests/test_replay_service
        pins it)."""
        return cls(axes=(AxisSpec("workers", n_workers, collective, sync,
                                  max_delay, staleness_bound),
                         AxisSpec("replay", n_shards, "allreduce", "bsp",
                                  max_delay, staleness_bound,
                                  role="replay")),
                   actors=None if actors is None else tuple(actors))

    @classmethod
    def parse(cls, spec: str, max_delay: int = 4,
              staleness_bound: int = 1, actors=None) -> "DistPlan":
        """Parse the CLI grammar: comma-separated axes, outermost first,
        each ``name=size[:collective[:sync[:role]]]``, e.g.

            hosts=2:allreduce:bsp,workers=2:gossip:asp
            workers=4:allreduce:bsp,shard=2:allreduce:bsp:shard
            workers=4:allreduce:bsp,shard=2:allreduce:bsp:zero3
            workers=2:allreduce:bsp,replay=2:allreduce:bsp:replay

        Role ``shard`` marks the ZeRO-2 learner-state sharding axis,
        ``zero3`` the full ZeRO-3 axis (params stored sharded too,
        gathered per use), ``replay`` the sharded replay-service axis
        (the group holds ONE logical replay buffer, 1/size per member;
        allreduce + bsp only); default ``data``. Empty specs, empty
        segments and duplicate axis names raise errors naming the
        offending input."""
        if not spec or not spec.strip():
            raise ValueError(
                "empty plan: expected comma-separated axes "
                "name=size[:collective[:sync[:role]]], e.g. "
                "'workers=4:allreduce:bsp'")
        axes = []
        for seg in spec.split(","):
            parts = seg.strip().split(":")
            if "=" not in parts[0]:
                raise ValueError(f"bad plan axis {seg!r}: expected "
                                 f"name=size[:collective[:sync[:role]]]")
            name, size = parts[0].split("=", 1)
            try:
                size = int(size)
            except ValueError:
                raise ValueError(f"bad plan axis {seg!r}: size "
                                 f"{size!r} is not an integer") from None
            collective = parts[1] if len(parts) > 1 else "allreduce"
            sync = parts[2] if len(parts) > 2 else "bsp"
            role = parts[3] if len(parts) > 3 else "data"
            if len(parts) > 4:
                raise ValueError(f"bad plan axis {seg!r}: too many ':' "
                                 f"(grammar is name=size[:collective"
                                 f"[:sync[:role]]])")
            axes.append(AxisSpec(name.strip(), size, collective,
                                 sync, max_delay, staleness_bound, role))
        return cls(axes=tuple(axes),
                   actors=None if actors is None else tuple(actors))

    # ---- derived shape ------------------------------------------------
    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.axes)

    @property
    def mesh_shape(self) -> Tuple[int, ...]:
        return tuple(a.size for a in self.axes)

    @property
    def n_devices(self) -> int:
        n = 1
        for a in self.axes:
            n *= a.size
        return n

    @property
    def ring_extra(self) -> int:
        """Worst-case total extra staleness: per-axis delays add."""
        return sum(a.ring_extra for a in self.axes)

    @property
    def pipeline_depth(self) -> int:
        """Trajectory-queue depth of the plan in the Trainer's
        ``pipeline=`` mode: per-axis staleness budgets add, exactly as
        the per-axis delay schedules add in the fused rendering. A pure
        bsp plan has depth 0 — the pipelined superstep degenerates to
        lockstep and stays bitwise the fused path (pinned in
        tests/test_pipeline.py)."""
        return sum(a.pipeline_depth for a in self.axes)

    @property
    def shard_axis(self) -> Optional[AxisSpec]:
        """The (single, validated) ZeRO shard-role axis — role `shard`
        (ZeRO-2) or `zero3` — or None."""
        for a in self.axes:
            if a.role in ("shard", "zero3"):
                return a
        return None

    @property
    def data_axes(self) -> Tuple[AxisSpec, ...]:
        return tuple(a for a in self.axes if a.role == "data")

    @property
    def shard_size(self) -> int:
        """Learner-state shard count (1 when no shard axis)."""
        ax = self.shard_axis
        return 1 if ax is None else ax.size

    @property
    def replay_axis(self) -> Optional[AxisSpec]:
        """The (single, validated) replay-role axis, or None."""
        for a in self.axes:
            if a.role == "replay":
                return a
        return None

    @property
    def replay_size(self) -> int:
        """Replay shard count (1 when no replay axis)."""
        ax = self.replay_axis
        return 1 if ax is None else ax.size

    @property
    def sim_shape(self) -> Tuple[int, ...]:
        """Mesh shape with the ACTIVE replay axis (size > 1) collapsed
        to 1 — the env grid: replay-group members replicate the rollout
        of their data position (the axis adds replay capacity, not
        sample throughput), so envs shard over the non-replay axes
        only. A size-1 replay axis stays a plain data axis (the no-op
        guarantee holds by construction)."""
        return tuple(1 if (a.role == "replay" and a.size > 1) else a.size
                     for a in self.axes)

    @property
    def sim_devices(self) -> int:
        """Device count of the env grid (`sim_shape`); equals
        `n_devices` on plans without an active replay axis."""
        n = 1
        for s in self.sim_shape:
            n *= s
        return n

    def describe(self) -> str:
        s = ",".join(f"{a.name}={a.size}:{a.collective}:{a.sync}"
                     + (f":{a.role}" if a.role != "data" else "")
                     for a in self.axes)
        if self.actors is not None:
            s += ";actors=" + ",".join(map(str, self.actors))
        return s

    # ---- mesh construction --------------------------------------------
    def validate_devices(self, n_available: int) -> None:
        """Clear error instead of silently slicing/wrapping devices."""
        if self.n_devices > n_available:
            shape = "x".join(f"{a.name}={a.size}" for a in self.axes)
            raise RuntimeError(
                f"DistPlan mesh ({shape}) needs {self.n_devices} devices "
                f"but only {n_available} {'is' if n_available == 1 else 'are'} "
                f"visible; set XLA_FLAGS=--xla_force_host_platform_"
                f"device_count={self.n_devices} before importing jax "
                f"(the rl_train CLI does this automatically)")

    def build_mesh(self, devices=None):
        """Mesh over the first `n_devices` visible devices, row-major:
        the device at mesh coordinates (i0, i1, ...) is flat device
        ``sum_a i_a * stride_a`` — the same order the flat plan uses, so
        nesting never permutes which envs/RNG streams a device owns."""
        from jax.sharding import Mesh
        devices = jax.devices() if devices is None else devices
        self.validate_devices(len(devices))
        devs = np.asarray(devices[:self.n_devices]).reshape(
            self.mesh_shape)
        return Mesh(devs, self.axis_names)

    # ---- compiled pieces consumed by the Trainer ----------------------
    def linear_index(self):
        """Traced flat device index inside shard_map (RNG stream id) —
        identical to the flat plan's `axis_index("workers")`."""
        idx = jax.lax.axis_index(self.axes[0].name)
        for a in self.axes[1:]:
            idx = idx * a.size + jax.lax.axis_index(a.name)
        return idx

    def sim_index(self):
        """Traced device index over the env grid (`sim_shape`) — the
        RNG stream id. Like `linear_index` but the ACTIVE replay axis
        contributes nothing, so every member of a replay group draws
        exactly the stream of its data position in the flat plan (the
        group replicates rollouts; only replay STORAGE is sharded). On
        plans without an active replay axis this is `linear_index`
        term-for-term — a size-1 replay axis contributes idx*1 + 0."""
        idx = None
        for a in self.axes:
            if a.role == "replay" and a.size > 1:
                continue
            i = jax.lax.axis_index(a.name)
            idx = i if idx is None else idx * a.size + i
        return jnp.zeros((), jnp.int32) if idx is None else idx

    def compile_collectives(self):
        """(grad_tx, param_tx) hooks: per-axis collectives applied
        innermost -> outermost. Consecutive allreduce axes fuse into one
        pmean over the axis-name tuple (bitwise the flat all-reduce);
        ps star-gathers per axis; gossip skips the grad exchange and
        ring-mixes params on its axis instead."""
        steps = []  # innermost -> outermost: ("allreduce"|"ps", names)
        for ax in reversed(self.axes):
            if ax.role == "replay" and ax.size > 1:
                # replay-group members compute identical gradients by
                # construction (same envs, same RNG streams, same
                # sampled batch — only replay STORAGE differs), so
                # there is nothing to exchange; skipping the axis keeps
                # the reduction association bitwise the flat plan's. A
                # size-1 replay axis participates like a data axis.
                continue
            if ax.collective == "allreduce":
                if steps and steps[-1][0] == "allreduce":
                    # fuse, keeping names outermost-first: the device
                    # iteration order of the fused all-reduce then
                    # matches the flat plan's, bitwise
                    steps[-1] = ("allreduce", (ax.name,) + steps[-1][1])
                else:
                    steps.append(("allreduce", (ax.name,)))
            elif ax.collective == "ps":
                steps.append(("ps", ax.name))
        gossip_axes = tuple(ax.name for ax in reversed(self.axes)
                            if ax.collective == "gossip")

        def grad_tx(grads):
            for kind, names in steps:
                grads = exchange_grads(grads, names, kind)
            return grads

        def param_tx(params):
            for name in gossip_axes:
                params = gossip_mix(params, name)
            return params

        return grad_tx, (param_tx if gossip_axes else None)

    def make_delay_schedule(self, n_steps: int, key):
        """(n_steps,) + mesh_shape int32 delays: per-axis §6 schedules
        broadcast over the other axes and summed. A single-axis plan
        consumes `key` exactly as the legacy path did (bitwise-identical
        schedules); multi-axis plans split it per axis."""
        total = jnp.zeros((n_steps,) + self.mesh_shape, jnp.int32)
        keys = ([key] if len(self.axes) == 1
                else list(jax.random.split(key, len(self.axes))))
        for i, ax in enumerate(self.axes):
            d = make_delays(SyncConfig(ax.sync, ax.size, ax.max_delay,
                                       ax.staleness_bound),
                            n_steps, keys[i])         # (n_steps, size)
            shape = [n_steps] + [1] * len(self.axes)
            shape[1 + i] = ax.size
            total = total + d.reshape(shape)
        return total

    def actor_schedule(self, superstep_idx: int, default: int) -> int:
        """Total env-shard count for superstep window `superstep_idx`
        (iteration // cfg.superstep — NOT the dispatch count, so fused
        and unfused fits reshard at the same iteration boundaries; the
        schedule cycles); `default` when the plan is not elastic."""
        if self.actors is None:
            return default
        return self.actors[superstep_idx % len(self.actors)]


# all-meta pytrees: plans flow through jit/closure boundaries as static
# config, never as traced leaves
jax.tree_util.register_static(AxisSpec)
jax.tree_util.register_static(DistPlan)
