"""V-trace off-policy correction (IMPALA, survey §6.1) — public API.

Dispatches to the Pallas kernel on TPU and the lax.scan reference
elsewhere; both share the oracle in kernels/vtrace/ref.py.
"""
from repro.kernels.common import interpret_mode
from repro.kernels.vtrace.ref import vtrace_ref


def vtrace(log_rhos, discounts, rewards, values, bootstrap,
           clip_rho=1.0, clip_c=1.0, use_kernel=False):
    if use_kernel and not interpret_mode():
        from repro.kernels.vtrace.ops import vtrace as vtrace_k
        return vtrace_k(log_rhos, discounts, rewards, values, bootstrap,
                        clip_rho=clip_rho, clip_c=clip_c)
    return vtrace_ref(log_rhos, discounts, rewards, values, bootstrap,
                      clip_rho=clip_rho, clip_c=clip_c)


def epsilon_correction(logp, eps=1e-6):
    """GA3C ε-correction (survey §6.1): bound log-prob away from -inf to
    avoid numerical instability in async gradient estimation."""
    import jax.numpy as jnp
    return jnp.log(jnp.exp(logp) + eps)
