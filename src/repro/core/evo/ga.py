"""Deep GA (Such et al. 2017; survey §7.2): gradient-free truncation
selection with the *compact seed-chain encoding* — an individual is the
list of mutation seeds that reconstructs it, so workers exchange a few
int32 seeds instead of parameter vectors."""
from __future__ import annotations

import dataclasses

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.core.rollout import episode_return


@dataclasses.dataclass(frozen=True)
class DeepGA:
    policy: object
    env: object
    pop_size: int = 32
    truncation: int = 8
    sigma: float = 0.05
    max_steps: int = 200
    chain_len: int = 16           # max generations encoded per individual

    def init(self, key):
        params = self.policy.init(key)
        theta0, unravel = jax.flatten_util.ravel_pytree(params)
        object.__setattr__(self, "_unravel", unravel)
        object.__setattr__(self, "_theta0", theta0)
        # population = seed chains (pop, chain_len); 0 = empty slot
        chains = jnp.zeros((self.pop_size, self.chain_len), jnp.uint32)
        lens = jnp.zeros((self.pop_size,), jnp.int32)
        return {"chains": chains, "lens": lens}

    # -- compact encoding reconstruction --------------------------------
    def reconstruct(self, chain, length):
        """θ = θ0 + σ Σ_i ε(seed_i) — rebuild params from the seed list."""
        def body(theta, i):
            seed = chain[i]
            eps = jax.random.normal(jax.random.PRNGKey(seed),
                                    theta.shape)
            theta = theta + jnp.where(i < length, self.sigma, 0.0) * eps
            return theta, None
        theta, _ = jax.lax.scan(body, self._theta0,
                                jnp.arange(self.chain_len))
        return theta

    def fitness(self, chain, length, key):
        theta = self.reconstruct(chain, length)
        return episode_return(self.policy, self._unravel(theta), self.env,
                              key, self.max_steps)

    def step(self, state, key):
        """One generation. Returns (state, best_fitness, comm_bytes)."""
        k_ev, k_sel, k_mut = jax.random.split(key, 3)
        keys = jax.random.split(k_ev, self.pop_size)
        fits = jax.vmap(self.fitness)(state["chains"], state["lens"], keys)
        _, top = jax.lax.top_k(fits, self.truncation)
        # children: pick a random parent among the elite, append a seed
        parents = jax.random.choice(k_sel, top, (self.pop_size,))
        new_seeds = jax.random.randint(
            k_mut, (self.pop_size,), 1, jnp.iinfo(jnp.int32).max
        ).astype(jnp.uint32)
        pc = state["chains"][parents]
        pl = state["lens"][parents]
        pos = jnp.minimum(pl, self.chain_len - 1)
        chains = jax.vmap(lambda c, i, s: c.at[i].set(s))(pc, pos,
                                                          new_seeds)
        lens = jnp.minimum(pl + 1, self.chain_len)
        # elitism: slot 0 keeps the best individual unmutated
        best = top[0]
        chains = chains.at[0].set(state["chains"][best])
        lens = lens.at[0].set(state["lens"][best])
        # survey §7.2: traffic = one uint32 seed + one f32 fitness each
        comm_bytes = 8 * self.pop_size
        return {"chains": chains, "lens": lens}, fits.max(), comm_bytes
