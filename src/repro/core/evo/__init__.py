from repro.core.evo.es import ES  # noqa: F401
from repro.core.evo.ga import DeepGA  # noqa: F401
from repro.core.evo.erl import ERL  # noqa: F401
