"""Evolution Strategies (Salimans et al. 2017; survey §7.1).

Antithetic sampling, rank-shaped fitness, seed-based perturbation
reconstruction. The survey's key scaling observation — communication per
worker is ONE scalar fitness per member, not a gradient vector — is
measured in benchmarks/sec7_evolution.py (`comm_bytes_per_step`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.core.rollout import episode_return


def _ravel(params):
    flat, unravel = jax.flatten_util.ravel_pytree(params)
    return flat, unravel


def centered_ranks(x):
    """Fitness shaping: map fitnesses to ranks in [-0.5, 0.5]."""
    ranks = jnp.argsort(jnp.argsort(x))
    return ranks.astype(jnp.float32) / (x.shape[0] - 1) - 0.5


@dataclasses.dataclass(frozen=True)
class ES:
    policy: object
    env: object
    pop_size: int = 32            # antithetic pairs: pop_size must be even
    sigma: float = 0.1
    lr: float = 0.05
    max_steps: int = 200

    def init(self, key):
        params = self.policy.init(key)
        theta, unravel = _ravel(params)
        object.__setattr__(self, "_unravel", unravel)
        return theta

    def unravel(self, theta):
        return self._unravel(theta)

    def fitness(self, theta, key):
        return episode_return(self.policy, self._unravel(theta), self.env,
                              key, self.max_steps)

    def step(self, theta, key):
        """One generation. Returns (theta, mean_fitness, comm_bytes)."""
        k_eps, k_ev = jax.random.split(key)
        half = self.pop_size // 2
        eps = jax.random.normal(k_eps, (half, theta.shape[0]))
        eps = jnp.concatenate([eps, -eps], axis=0)      # antithetic
        pop = theta[None] + self.sigma * eps
        # common random numbers: every member evaluated on the SAME
        # episode seed — removes env-reset noise from the fitness
        # comparison (standard ES variance reduction)
        keys = jnp.broadcast_to(k_ev, (self.pop_size,) + k_ev.shape)
        fits = jax.vmap(self.fitness)(pop, keys)
        shaped = centered_ranks(fits)
        grad = (shaped[:, None] * eps).mean(0) / self.sigma
        theta = theta + self.lr * grad
        # survey §7.1: inter-worker traffic = one f32 fitness per member
        comm_bytes = 4 * self.pop_size
        return theta, fits.mean(), comm_bytes
