"""Hybrid Evolution-guided RL (Khadka & Tumer 2018; survey §7.3).

A GA population of policies explores and fills a shared replay buffer;
a gradient learner (actor-critic on the replay data) trains in parallel
and is periodically *injected* into the population, replacing the worst
member — combining evolutionary exploration with backprop sample reuse.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.core.replay import UniformReplay
from repro.core.rollout import episode_return


@dataclasses.dataclass(frozen=True)
class ERL:
    policy: object                 # continuous MLPPolicy
    env: object
    pop_size: int = 8
    elite: int = 2
    sigma: float = 0.05
    gamma: float = 0.99
    inject_every: int = 2
    max_steps: int = 200
    replay_capacity: int = 20000

    def init(self, key):
        ks = jax.random.split(key, self.pop_size + 1)
        thetas = []
        for i in range(self.pop_size):
            p = self.policy.init(ks[i])
            flat, unravel = jax.flatten_util.ravel_pytree(p)
            thetas.append(flat)
        object.__setattr__(self, "_unravel", unravel)
        learner = self.policy.init(ks[-1])
        lflat, _ = jax.flatten_util.ravel_pytree(learner)
        replay = UniformReplay(self.replay_capacity)
        spec = self.env.spec
        obs_zero = jnp.zeros(spec.observation.shape,
                             spec.observation.dtype)
        example = {"obs": obs_zero,
                   "action": jnp.zeros((spec.act_dim,)),
                   "reward": jnp.zeros(()),
                   "next_obs": obs_zero,
                   "done": jnp.zeros((), bool)}
        return {"pop": jnp.stack(thetas), "learner": lflat,
                "replay": replay.init(example), "gen": 0}, replay

    # ---- population rollouts also fill the replay buffer --------------
    def evaluate_and_collect(self, state, replay, key):
        def run_member(theta, k):
            params = self._unravel(theta)
            # stochastic rollout for diversity + transition collection
            def step(carry, kk):
                s, done = carry
                obs = self.env.obs(s)
                a, _ = self.policy.sample(params, obs, kk)
                ns, nobs, r, nd = self.env.step(s, a)
                trans = {"obs": obs, "action": a.reshape(-1), "reward": r,
                         "next_obs": nobs, "done": nd}
                ns = jax.tree_util.tree_map(
                    lambda x, y: jnp.where(done, x, y), s, ns)
                return (ns, done | nd), (trans, jnp.where(done, 0.0, r))
            s0 = self.env.reset(k)
            (_, _), (trans, rews) = jax.lax.scan(
                step, (s0, jnp.zeros((), bool)),
                jax.random.split(k, self.max_steps))
            return trans, rews.sum()

        keys = jax.random.split(key, self.pop_size)
        trans, fits = jax.vmap(run_member)(state["pop"], keys)
        flat_trans = jax.tree_util.tree_map(
            lambda a: a.reshape((-1,) + a.shape[2:]), trans)
        rstate = replay.add_batch(state["replay"], flat_trans)
        return dict(state, replay=rstate), fits

    # ---- gradient learner (advantage-free actor-critic on replay) -----
    def learner_loss(self, params, batch):
        pi, v = self.policy.apply(params, batch["obs"])
        v_next = self.policy.apply(params, batch["next_obs"])[1]
        target = batch["reward"] + self.gamma * (
            1 - batch["done"].astype(jnp.float32)) * \
            jax.lax.stop_gradient(v_next)
        td = target - v
        logp, _, _ = self.policy.log_prob(params, batch["obs"],
                                          batch["action"][..., 0]
                                          if self.policy.discrete
                                          else batch["action"])
        return (jnp.mean(jnp.square(td))
                - jnp.mean(logp * jax.lax.stop_gradient(td)))

    def step(self, state, replay, key, optimizer, opt_state,
             learner_updates=8, batch_size=128):
        """One ERL generation."""
        k1, k2, k3, k4 = jax.random.split(key, 4)
        state, fits = self.evaluate_and_collect(state, replay, k1)
        # GA: truncation selection + gaussian mutation
        _, top = jax.lax.top_k(fits, self.elite)
        parents = jax.random.choice(k2, top, (self.pop_size,))
        noise = self.sigma * jax.random.normal(k3, state["pop"].shape)
        pop = state["pop"][parents] + noise
        pop = pop.at[0].set(state["pop"][top[0]])      # elitism
        # gradient learner on replay
        lparams = self._unravel(state["learner"])
        for i in range(learner_updates):
            batch, _ = replay.sample(state["replay"],
                                     jax.random.fold_in(k4, i),
                                     batch_size)
            _, grads = jax.value_and_grad(self.learner_loss)(lparams,
                                                             batch)
            lparams, opt_state = optimizer.apply(lparams, opt_state,
                                                 grads)
        lflat, _ = jax.flatten_util.ravel_pytree(lparams)
        # periodic injection: learner replaces the worst member
        gen = state["gen"] + 1
        if gen % self.inject_every == 0:
            worst = jnp.argmin(fits)
            pop = pop.at[worst].set(lflat)
        state = dict(state, pop=pop, learner=lflat, gen=gen)
        return state, opt_state, fits
