"""Shared kernel helpers: interpret-mode selection + compiler params."""
import jax

try:  # TPU compiler params — name moved across JAX versions
    from jax.experimental.pallas import tpu as pltpu
    if hasattr(pltpu, "TPUCompilerParams"):
        CompilerParams = pltpu.TPUCompilerParams
    else:
        CompilerParams = pltpu.CompilerParams
except Exception:  # pragma: no cover
    pltpu = None
    CompilerParams = None


def interpret_mode() -> bool:
    """Pallas-TPU kernels execute in interpret mode off-TPU (CPU CI)."""
    return jax.default_backend() != "tpu"


def compiler_params(dimension_semantics):
    if CompilerParams is None or interpret_mode():
        return None
    try:
        return CompilerParams(dimension_semantics=dimension_semantics)
    except TypeError:  # pragma: no cover
        return None
