"""Jit'd wrapper: pad (C,d,f) to tile multiples, call the Pallas gmm."""
import jax.numpy as jnp

from repro.kernels.gmm.kernel import gmm_ecd


def gmm(x, w, bc=128, bf=128, bd=512):
    """x: (E,C,d) @ w: (E,d,f) -> (E,C,f), per expert."""
    E, C, d = x.shape
    f = w.shape[-1]
    bc_, bf_, bd_ = min(bc, C), min(bf, f), min(bd, d)
    pc, pf, pd = (-C) % bc_, (-f) % bf_, (-d) % bd_
    xp = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    wp = jnp.pad(w.astype(x.dtype), ((0, 0), (0, pd), (0, pf)))
    o = gmm_ecd(xp, wp, bc=bc_, bf=bf_, bd=bd_)
    return o[:, :C, :f]
