"""Pure-jnp oracle for the grouped (per-expert) matmul."""
import jax.numpy as jnp


def gmm_ref(x, w):
    """x: (E,C,d); w: (E,d,f) -> (E,C,f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
