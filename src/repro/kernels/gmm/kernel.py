"""Pallas-TPU grouped matmul (per-expert matmul for MoE FFN).

Grid (E, nc, nf, nd): the contraction axis d is innermost/"arbitrary" with
an f32 VMEM accumulator; (expert, row-tile, col-tile) are parallel.
VMEM per step: bc*bd (x) + bd*bf (w) + bc*bf (acc) — defaults
128·512·4·3 ≈ 0.8 MiB. MXU-aligned tiles (multiples of 128)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pltpu, interpret_mode, compiler_params


def _kernel(xref, wref, oref, accref, *, nd):
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        accref[...] = jnp.zeros_like(accref)

    accref[...] += jax.lax.dot_general(
        xref[0].astype(jnp.float32), wref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(idd == nd - 1)
    def _fin():
        oref[0] = accref[...].astype(oref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd"))
def gmm_ecd(x, w, *, bc=128, bf=128, bd=512):
    """x: (E,C,d); w: (E,d,f); C%bc==0, f%bf==0, d%bd==0 (wrapper pads)."""
    E, C, d = x.shape
    f = w.shape[-1]
    nc, nf, nd = C // bc, f // bf, d // bd
    kernel = functools.partial(_kernel, nd=nd)
    scratch = None
    if pltpu is not None:
        scratch = [pltpu.VMEM((bc, bf), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, if_, id_: (e, ic, id_)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, if_, id_: (e, id_, if_)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda e, ic, if_, id_: (e, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=scratch,
        compiler_params=compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret_mode(),
    )(x, w)
