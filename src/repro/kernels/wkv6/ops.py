"""Jit'd wrapper: pad T, call the chunked Pallas WKV-6 kernel."""
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_btHN


def wkv6(r, k, v, logw, u, chunk=64):
    """r,k,v,logw: (B,T,H,N); u: (H,N). Zero initial state."""
    T = r.shape[1]
    pad = (-T) % chunk
    if pad:
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(a, pad4) for a in (r, k, v))
        logw = jnp.pad(logw, pad4)
    y = wkv6_btHN(r.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32), logw.astype(jnp.float32),
                  u.astype(jnp.float32), chunk=chunk)
    return y[:, :T]
