"""Per-timestep scan oracle for the RWKV-6 WKV recurrence."""
import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u, state=None):
    """r,k,v,logw: (B,T,H,N) f32; u: (H,N). Returns (y (B,T,H,N), S).
        y_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    """
    B, T, H, N = r.shape
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S, xs):
        rt, kt, vt, lwt = xs
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt,
                       S + u[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, logw))
    S, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), S
