"""Pallas-TPU chunked WKV-6 kernel.

Grid (B, H, nc) — chunk axis innermost/"arbitrary"; the (N,N) recurrent
state lives in VMEM scratch and is re-initialized whenever a new (b,h)
row starts (ic==0). Within a chunk, decay products are pairwise
exp(cum_t − cum_j) (differences of non-positive logs — no overflow), so
the intra-chunk part is dense matmul work for the MXU rather than a
length-T serial dependence; only the chunk boundary is sequential.
VMEM per step: 4·L·N inputs + L·L·N decay tensor + N·N state
(L=64, N=64 → ~1.3 MiB f32).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pltpu, interpret_mode, compiler_params


def _kernel(rref, kref, vref, wref, uref, yref, Sref, *, L, N):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        Sref[...] = jnp.zeros_like(Sref)

    r = rref[0, :, 0, :].astype(jnp.float32)        # (L,N)
    k = kref[0, :, 0, :].astype(jnp.float32)
    v = vref[0, :, 0, :].astype(jnp.float32)
    lw = wref[0, :, 0, :].astype(jnp.float32)
    u = uref[0].astype(jnp.float32)                 # (N,)
    S = Sref[...]

    c = jnp.cumsum(lw, axis=0)
    cprev = c - lw
    dmat = cprev[:, None, :] - c[None, :, :]        # (t, j, N) <= 0 for t>j
    tri = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    decay = jnp.where(tri[..., None], jnp.exp(dmat), 0.0)
    score = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=-1)  # (t,j)
    sdiag = jnp.sum(r * u[None, :] * k, axis=-1)    # (t,)
    y = jax.lax.dot_general(score, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + sdiag[:, None] * v
    y = y + jax.lax.dot_general(r * jnp.exp(cprev), S,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    cl = c[-1]
    kd = k * jnp.exp(cl[None, :] - c)
    S_new = jnp.exp(cl)[:, None] * S + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    Sref[...] = S_new
    yref[0, :, 0, :] = y.astype(yref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_btHN(r, k, v, logw, u, *, chunk=64):
    """r,k,v,logw: (B,T,H,N); u: (H,N); T % chunk == 0 (wrapper pads).
    Zero initial state. Returns y (B,T,H,N) f32."""
    B, T, H, N = r.shape
    nc = T // chunk
    kernel = functools.partial(_kernel, L=chunk, N=N)
    spec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, ic: (b, ic, h, 0))
    scratch = None
    if pltpu is not None:
        scratch = [pltpu.VMEM((N, N), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, N), lambda b, h, ic: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, N), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret_mode(),
    )(r, k, v, logw, u)
