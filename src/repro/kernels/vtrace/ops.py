"""Jit'd wrapper: pad batch, call the Pallas V-trace kernel."""
import jax
import jax.numpy as jnp

from repro.kernels.vtrace.kernel import vtrace_tb


def vtrace(log_rhos, discounts, rewards, values, bootstrap,
           clip_rho=1.0, clip_c=1.0, bb=128):
    T, B = log_rhos.shape
    bb = min(bb, B)
    pad = (-B) % bb
    if pad:
        p2 = ((0, 0), (0, pad))
        log_rhos, discounts, rewards, values = (
            jnp.pad(a, p2) for a in (log_rhos, discounts, rewards, values))
        bootstrap = jnp.pad(bootstrap, ((0, pad),))
    vs, adv = vtrace_tb(log_rhos.astype(jnp.float32),
                        discounts.astype(jnp.float32),
                        rewards.astype(jnp.float32),
                        values.astype(jnp.float32),
                        bootstrap.astype(jnp.float32),
                        clip_rho=clip_rho, clip_c=clip_c, bb=bb)
    return (jax.lax.stop_gradient(vs[:, :B]),
            jax.lax.stop_gradient(adv[:, :B]))
