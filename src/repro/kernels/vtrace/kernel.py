"""Pallas-TPU V-trace kernel.

The backward recursion is inherently serial in T, but embarrassingly
parallel in batch — grid (nb,) tiles the batch across cores while the
whole (T, bb) trajectory block sits in VMEM (T≤2048, bb=128 → ~4 MiB for
the four inputs). One fori_loop runs the recursion entirely in-register.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_mode, compiler_params


def _kernel(rho_ref, disc_ref, rew_ref, val_ref, boot_ref,
            vs_ref, adv_ref, *, T, clip_rho, clip_c):
    rhos = jnp.minimum(clip_rho, jnp.exp(rho_ref[...]))    # (T,bb)
    cs = jnp.minimum(clip_c, jnp.exp(rho_ref[...]))
    disc = disc_ref[...]
    rew = rew_ref[...]
    val = val_ref[...]
    boot = boot_ref[...]                                   # (1,bb)

    def step(i, carry):
        acc, vs = carry
        t = T - 1 - i
        v_tp1 = jnp.where(t == T - 1, boot[0], val[jnp.minimum(t + 1,
                                                               T - 1)])
        delta = rhos[t] * (rew[t] + disc[t] * v_tp1 - val[t])
        acc = delta + disc[t] * cs[t] * acc
        vs = vs.at[t].set(val[t] + acc)
        return acc, vs

    acc0 = jnp.zeros_like(boot[0])
    vs0 = jnp.zeros_like(val)
    _, vs = jax.lax.fori_loop(0, T, step, (acc0, vs0))
    vs_tp1 = jnp.concatenate([vs[1:], boot], axis=0)
    adv = rhos * (rew + disc * vs_tp1 - val)
    vs_ref[...] = vs
    adv_ref[...] = adv


@functools.partial(jax.jit, static_argnames=("clip_rho", "clip_c", "bb"))
def vtrace_tb(log_rhos, discounts, rewards, values, bootstrap,
              clip_rho=1.0, clip_c=1.0, bb=128):
    """Inputs (T,B) f32 time-major, bootstrap (B,); B % bb == 0
    (wrapper pads). Returns (vs, pg_adv)."""
    T, B = log_rhos.shape
    nb = B // bb
    kernel = functools.partial(_kernel, T=T, clip_rho=clip_rho,
                               clip_c=clip_c)
    spec = pl.BlockSpec((T, bb), lambda ib: (0, ib))
    vs, adv = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, bb), lambda ib: (0, ib))],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((T, B), jnp.float32),
                   jax.ShapeDtypeStruct((T, B), jnp.float32)),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret_mode(),
    )(log_rhos, discounts, rewards, values, bootstrap[None])
    return vs, adv
