"""Reference V-trace (IMPALA, Espeholt et al. 2018) via lax.scan.

    δ_t  = ρ_t (r_t + γ_t V_{t+1} − V_t)
    vs_t = V_t + δ_t + γ_t c_t (vs_{t+1} − V_{t+1})
    adv_t = ρ_t (r_t + γ_t vs_{t+1} − V_t)
with ρ_t = min(ρ̄, w_t), c_t = min(c̄, w_t), w_t the IS ratio.
"""
import jax
import jax.numpy as jnp


def vtrace_ref(log_rhos, discounts, rewards, values, bootstrap,
               clip_rho=1.0, clip_c=1.0):
    """All inputs (T, B) time-major; values V_t; bootstrap V_T (B,).
    Returns (vs (T,B), pg_advantages (T,B))."""
    rhos = jnp.minimum(clip_rho, jnp.exp(log_rhos))
    cs = jnp.minimum(clip_c, jnp.exp(log_rhos))
    values_tp1 = jnp.concatenate([values[1:], bootstrap[None]], axis=0)
    deltas = rhos * (rewards + discounts * values_tp1 - values)

    def body(acc, xs):
        delta, disc, c = xs
        acc = delta + disc * c * acc
        return acc, acc

    _, dvs = jax.lax.scan(body, jnp.zeros_like(bootstrap),
                          (deltas, discounts, cs), reverse=True)
    vs = values + dvs
    vs_tp1 = jnp.concatenate([vs[1:], bootstrap[None]], axis=0)
    pg_adv = rhos * (rewards + discounts * vs_tp1 - values)
    return jax.lax.stop_gradient(vs), jax.lax.stop_gradient(pg_adv)
