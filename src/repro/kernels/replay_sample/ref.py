"""Reference fused prioritized sampling (Ape-X, survey §3.1).

One pass from raw priorities to (indices, importance weights):

    logits_i = α log(p_i + ε)            (masked to filled slots)
    draw:      top-n of logits_i + g_i   (g_i ~ Gumbel(0,1) supplied by
               the caller — Gumbel-top-k, i.e. sampling WITHOUT
               replacement proportional to p_i^α)
    weights:   w_j ∝ (N π_{idx_j})^{-β}, normalized to max 1, with
               π gathered straight from the chosen logits — no
               full-capacity softmax materialization.

The Pallas kernel (kernel.py) computes the identical function; this
oracle is the parity target. The Gumbel noise is an explicit input so
kernel and ref are comparable draw-for-draw.
"""
import jax
import jax.numpy as jnp


def prioritized_sample_ref(prio, size, gumbel, n, alpha=0.6, beta=0.4,
                           eps=1e-6):
    """prio (C,) raw priorities, size scalar int (filled slots), gumbel
    (C,) standard Gumbel noise. Returns (idx (n,) int32, w (n,) f32).

    Degenerate regime n > size (avoid it — the draw is no longer
    without-replacement): top-k ranks all `size` filled slots first, so
    the surplus positions repeat the top draw instead of ever touching
    an unfilled slot; their weights are the top draw's real weight,
    never a fabricated max-weight zero transition."""
    C = prio.shape[0]
    nvalid = jnp.maximum(size, 1)
    valid = jnp.arange(C) < nvalid
    logits = jnp.where(valid, alpha * jnp.log(prio + eps), -jnp.inf)
    scores = jnp.where(valid, logits + gumbel, -jnp.inf)
    _, idx = jax.lax.top_k(scores, n)
    idx = jnp.where(jnp.arange(n) < nvalid, idx, idx[0]).astype(
        jnp.int32)
    return idx, prioritized_weights_ref(prio, size, idx, alpha, beta,
                                        eps)


def prioritized_weights_ref(prio, size, idx, alpha=0.6, beta=0.4,
                            eps=1e-6):
    """IS weights for already-chosen slots `idx` (n,) against the FULL
    (C,) priority vector — the weight half of prioritized_sample_ref,
    expression-for-expression (so splitting draw from weighting changes
    nothing bitwise). The sharded replay service reuses this verbatim:
    it all-gathers the global priority vector and normalizes against
    the GLOBAL partition function, keeping sharded IS weights bitwise
    the single-buffer draw's."""
    C = prio.shape[0]
    nvalid = jnp.maximum(size, 1)
    valid = jnp.arange(C) < nvalid
    logits = jnp.where(valid, alpha * jnp.log(prio + eps), -jnp.inf)
    # π_idx without materializing softmax(logits): gather the chosen
    # logits, normalize by the (scalar) partition function.
    m = jnp.max(logits)
    Z = jnp.sum(jnp.where(valid, jnp.exp(logits - m), 0.0))
    p = jnp.exp(logits[idx] - m) / Z
    w = (nvalid * p + 1e-12) ** (-beta)
    return w / jnp.maximum(w.max(), 1e-12)


def shard_gumbel_topk_ref(prio, nvalid_local, gumbel, k, alpha=0.6,
                          eps=1e-6):
    """Per-shard half of the sharded draw: the top-k candidate (score,
    local index) pairs over this shard's (chunk,) slice of priorities
    and Gumbel noise. Returns (scores (k,) f32, idx (k,) int32), scores
    descending.

    `nvalid_local` counts the valid slots IN THIS SHARD — the caller
    derives it as clip(global_nvalid - r*chunk, 0, chunk), keeping the
    global max(size, 1) guard with the caller, so an empty shard
    contributes only -inf candidates (there is deliberately NO local
    guard here). The masking/score expressions are verbatim
    prioritized_sample_ref's, so concatenating every shard's slice
    reproduces the flat score vector bitwise; because top_k is stable
    (ties break toward the lower input position) and candidates are
    merged shard-major, the global top-n over per-shard top-k
    candidates selects the identical index sequence as one top-n over
    the flat vector whenever n <= k per shard."""
    C = prio.shape[0]
    valid = jnp.arange(C) < nvalid_local
    logits = jnp.where(valid, alpha * jnp.log(prio + eps), -jnp.inf)
    scores = jnp.where(valid, logits + gumbel, -jnp.inf)
    s, idx = jax.lax.top_k(scores, k)
    return s, idx.astype(jnp.int32)
