"""Reference fused prioritized sampling (Ape-X, survey §3.1).

One pass from raw priorities to (indices, importance weights):

    logits_i = α log(p_i + ε)            (masked to filled slots)
    draw:      top-n of logits_i + g_i   (g_i ~ Gumbel(0,1) supplied by
               the caller — Gumbel-top-k, i.e. sampling WITHOUT
               replacement proportional to p_i^α)
    weights:   w_j ∝ (N π_{idx_j})^{-β}, normalized to max 1, with
               π gathered straight from the chosen logits — no
               full-capacity softmax materialization.

The Pallas kernel (kernel.py) computes the identical function; this
oracle is the parity target. The Gumbel noise is an explicit input so
kernel and ref are comparable draw-for-draw.
"""
import jax
import jax.numpy as jnp


def prioritized_sample_ref(prio, size, gumbel, n, alpha=0.6, beta=0.4,
                           eps=1e-6):
    """prio (C,) raw priorities, size scalar int (filled slots), gumbel
    (C,) standard Gumbel noise. Returns (idx (n,) int32, w (n,) f32).

    Degenerate regime n > size (avoid it — the draw is no longer
    without-replacement): top-k ranks all `size` filled slots first, so
    the surplus positions repeat the top draw instead of ever touching
    an unfilled slot; their weights are the top draw's real weight,
    never a fabricated max-weight zero transition."""
    C = prio.shape[0]
    nvalid = jnp.maximum(size, 1)
    valid = jnp.arange(C) < nvalid
    logits = jnp.where(valid, alpha * jnp.log(prio + eps), -jnp.inf)
    scores = jnp.where(valid, logits + gumbel, -jnp.inf)
    _, idx = jax.lax.top_k(scores, n)
    idx = jnp.where(jnp.arange(n) < nvalid, idx, idx[0]).astype(
        jnp.int32)
    # π_idx without materializing softmax(logits): gather the chosen
    # logits, normalize by the (scalar) partition function.
    m = jnp.max(logits)
    Z = jnp.sum(jnp.where(valid, jnp.exp(logits - m), 0.0))
    p = jnp.exp(logits[idx] - m) / Z
    w = (nvalid * p + 1e-12) ** (-beta)
    return idx, w / jnp.maximum(w.max(), 1e-12)
