"""Pallas-TPU fused prioritized-sampling kernel.

priorities -> α-scaled log-weights -> Gumbel-top-k draw -> IS weights,
all in one kernel invocation: the full (1, C) priority vector lives in
VMEM (C = replay capacity; 1M slots ≈ 4 MiB) and never materializes a
capacity-sized softmax — the partition function reduces to one scalar
and only the n chosen logits are exponentiated for weights. The top-n
draw is n rounds of argmax+mask over the in-VMEM scores (n·C VPU work,
n ≲ 256), entirely in-register.

With fewer filled slots than n (avoid it — the draw is no longer
without-replacement), surplus positions repeat the top draw exactly as
the ref oracle does: unfilled slots are never returned.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_mode, compiler_params

_NEG = -3.4e38  # -inf stand-in: avoids inf-inf NaNs on the VPU


def _kernel(prio_ref, gumbel_ref, size_ref, idx_ref, w_ref,
            *, n, C, alpha, beta, eps):
    size = size_ref[0, 0]
    nvalid = jnp.maximum(size, 1)
    col = jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
    valid = col < nvalid
    logits = jnp.where(valid, alpha * jnp.log(prio_ref[...] + eps), _NEG)
    scores = jnp.where(valid, logits + gumbel_ref[...], _NEG)

    def draw(i, carry):
        scores, idxs, chosen = carry
        j = jnp.argmax(scores).astype(jnp.int32)   # (1,C) flat == column
        hit = col == j
        idxs = idxs.at[0, i].set(j)
        chosen = chosen.at[0, i].set(jnp.sum(jnp.where(hit, logits, 0.0)))
        scores = jnp.where(hit, _NEG, scores)
        return scores, idxs, chosen

    _, idxs, chosen = jax.lax.fori_loop(
        0, n, draw, (scores, jnp.zeros((1, n), jnp.int32),
                     jnp.zeros((1, n), jnp.float32)))
    # n > size fallback: the first `size` positions hold every filled
    # slot (their scores dominate _NEG); surplus positions repeat the
    # top draw — matches ref.py, never returns an unfilled slot
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    surplus = pos >= nvalid
    idxs = jnp.where(surplus, idxs[0, 0], idxs)
    chosen = jnp.where(surplus, chosen[0, 0], chosen)

    m = jnp.max(jnp.where(valid, logits, _NEG))
    Z = jnp.sum(jnp.where(valid, jnp.exp(logits - m), 0.0))
    p = jnp.exp(chosen - m) / Z
    w = (nvalid.astype(jnp.float32) * p + 1e-12) ** (-beta)
    idx_ref[...] = idxs
    w_ref[...] = w / jnp.maximum(jnp.max(w), 1e-12)


def _topk_kernel(prio_ref, gumbel_ref, nvalid_ref, idx_ref, s_ref,
                 *, k, C, alpha, eps):
    """Per-shard candidate draw for the sharded replay service: the
    masking/score arithmetic of `_kernel` (verbatim, minus the weight
    epilogue — the service computes weights against the GLOBAL priority
    mass) followed by k rounds of argmax+mask. `nvalid_ref` is the
    LOCAL valid count; the global max(size, 1) guard stays with the
    caller, so an empty shard yields only _NEG candidates."""
    nvalid = nvalid_ref[0, 0]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
    valid = col < nvalid
    logits = jnp.where(valid, alpha * jnp.log(prio_ref[...] + eps), _NEG)
    scores = jnp.where(valid, logits + gumbel_ref[...], _NEG)

    def draw(i, carry):
        live, idxs, vals = carry
        j = jnp.argmax(live).astype(jnp.int32)    # (1,C) flat == column
        hit = col == j
        idxs = idxs.at[0, i].set(j)
        vals = vals.at[0, i].set(jnp.sum(jnp.where(hit, scores, 0.0)))
        live = jnp.where(hit, _NEG, live)
        return live, idxs, vals

    _, idxs, vals = jax.lax.fori_loop(
        0, k, draw, (scores, jnp.zeros((1, k), jnp.int32),
                     jnp.zeros((1, k), jnp.float32)))
    # surplus positions (k > nvalid): the argmax loop redraws slot 0
    # once everything is _NEG, but top_k over the flat vector walks the
    # remaining -inf slots in index order — indices nvalid, nvalid+1,
    # ..., i.e. position i holds index i. Rewrite to match the ref
    # bitwise; the merge never selects these unless the batch itself is
    # degenerate (overwritten by the caller's global-guard rule anyway).
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    surplus = pos >= nvalid
    idxs = jnp.where(surplus, pos, idxs)
    vals = jnp.where(surplus, _NEG, vals)
    idx_ref[...] = idxs
    s_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("k", "alpha", "eps"))
def shard_topk_c(prio, gumbel, nvalid, k, alpha=0.6, eps=1e-6):
    """prio/gumbel (1,C) f32, nvalid (1,1) int32 LOCAL valid count.
    -> (scores (1,k) f32 descending with _NEG for invalid, idx (1,k)
    i32)."""
    C = prio.shape[1]
    kernel = functools.partial(_topk_kernel, k=k, C=C, alpha=alpha,
                               eps=eps)
    spec = pl.BlockSpec((1, C), lambda: (0, 0))
    out_spec = pl.BlockSpec((1, k), lambda: (0, 0))
    idx, s = pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[spec, spec, pl.BlockSpec((1, 1), lambda: (0, 0))],
        out_specs=(out_spec, out_spec),
        out_shape=(jax.ShapeDtypeStruct((1, k), jnp.int32),
                   jax.ShapeDtypeStruct((1, k), jnp.float32)),
        compiler_params=compiler_params(()),
        interpret=interpret_mode(),
    )(prio, gumbel, nvalid)
    return s, idx


@functools.partial(jax.jit,
                   static_argnames=("n", "alpha", "beta", "eps"))
def prioritized_sample_c(prio, gumbel, size, n, alpha=0.6, beta=0.4,
                         eps=1e-6):
    """prio/gumbel (1,C) f32, size (1,1) int32. -> (idx (1,n) i32,
    w (1,n) f32)."""
    C = prio.shape[1]
    kernel = functools.partial(_kernel, n=n, C=C, alpha=alpha, beta=beta,
                               eps=eps)
    spec = pl.BlockSpec((1, C), lambda: (0, 0))
    out_spec = pl.BlockSpec((1, n), lambda: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[spec, spec, pl.BlockSpec((1, 1), lambda: (0, 0))],
        out_specs=(out_spec, out_spec),
        out_shape=(jax.ShapeDtypeStruct((1, n), jnp.int32),
                   jax.ShapeDtypeStruct((1, n), jnp.float32)),
        compiler_params=compiler_params(()),
        interpret=interpret_mode(),
    )(prio, gumbel, size)
