"""Jit'd wrapper: lift (C,) priorities into the fused Pallas
prioritized-sampling kernel's (1, C) layout."""
import jax.numpy as jnp

from repro.kernels.replay_sample.kernel import prioritized_sample_c


def prioritized_sample(prio, size, gumbel, n, alpha=0.6, beta=0.4,
                       eps=1e-6):
    """prio (C,) raw priorities, size scalar int32, gumbel (C,) standard
    Gumbel noise. Returns (idx (n,) int32, w (n,) f32)."""
    idx, w = prioritized_sample_c(
        prio.astype(jnp.float32)[None],
        gumbel.astype(jnp.float32)[None],
        jnp.asarray(size, jnp.int32).reshape(1, 1),
        n=n, alpha=float(alpha), beta=float(beta), eps=float(eps))
    return idx[0], w[0]
