"""Jit'd wrappers: lift (C,) priorities into the fused Pallas
prioritized-sampling kernels' (1, C) layout."""
import jax.numpy as jnp

from repro.kernels.replay_sample.kernel import (_NEG,
                                                prioritized_sample_c,
                                                shard_topk_c)


def prioritized_sample(prio, size, gumbel, n, alpha=0.6, beta=0.4,
                       eps=1e-6):
    """prio (C,) raw priorities, size scalar int32, gumbel (C,) standard
    Gumbel noise. Returns (idx (n,) int32, w (n,) f32)."""
    idx, w = prioritized_sample_c(
        prio.astype(jnp.float32)[None],
        gumbel.astype(jnp.float32)[None],
        jnp.asarray(size, jnp.int32).reshape(1, 1),
        n=n, alpha=float(alpha), beta=float(beta), eps=float(eps))
    return idx[0], w[0]


def shard_topk(prio, nvalid, gumbel, k, alpha=0.6, eps=1e-6):
    """prio (chunk,) raw priorities of ONE replay shard, nvalid scalar
    int32 LOCAL valid count, gumbel (chunk,) this shard's slice of the
    global Gumbel noise. Returns (scores (k,) f32, idx (k,) int32).
    The kernel masks with the finite _NEG stand-in; restore -inf here
    so the candidate scores match shard_gumbel_topk_ref bitwise."""
    s, idx = shard_topk_c(
        prio.astype(jnp.float32)[None],
        gumbel.astype(jnp.float32)[None],
        jnp.asarray(nvalid, jnp.int32).reshape(1, 1),
        k=k, alpha=float(alpha), eps=float(eps))
    s = s[0]
    return jnp.where(s == jnp.float32(_NEG), -jnp.inf, s), idx[0]
