"""Jit'd wrappers: pad batch, call the Pallas reverse-scan kernel, and
express GAE / n-step returns in terms of it (elementwise prologues fuse
into the surrounding XLA program; the serial recursion runs in-kernel).
"""
import jax.numpy as jnp

from repro.kernels.advantages.kernel import discounted_return_tb


def discounted_return(base, coef, init, bb=128):
    T, B = base.shape
    bb = min(bb, B)
    pad = (-B) % bb
    if pad:
        p2 = ((0, 0), (0, pad))
        base, coef = (jnp.pad(a, p2) for a in (base, coef))
        init = jnp.pad(init, ((0, pad),))
    out = discounted_return_tb(base.astype(jnp.float32),
                               coef.astype(jnp.float32),
                               init.astype(jnp.float32), bb=bb)
    return out[:, :B]


def gae(rewards, values, dones, bootstrap, gamma=0.99, lam=0.95, bb=128):
    """Time-major (T,B). Returns (advantages, returns)."""
    values_tp1 = jnp.concatenate([values[1:], bootstrap[None]], axis=0)
    nonterm = 1.0 - dones.astype(jnp.float32)
    deltas = rewards + gamma * nonterm * values_tp1 - values
    adv = discounted_return(deltas, gamma * lam * nonterm,
                            jnp.zeros_like(bootstrap), bb=bb)
    return adv, adv + values


def nstep_return(rewards, dones, bootstrap, gamma=0.99, bb=128):
    discounts = gamma * (1.0 - dones.astype(jnp.float32))
    return discounted_return(rewards, discounts, bootstrap, bb=bb)
