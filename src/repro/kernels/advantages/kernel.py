"""Pallas-TPU reverse-scan kernel for GAE / n-step returns.

One kernel serves every advantage estimator reducible to the linear
recurrence `out_t = base_t + coef_t * out_{t+1}` (see ref.py): the
recursion is serial in T but embarrassingly parallel in batch, so the
grid (nb,) tiles the batch across cores while the whole (T, bb) block
sits in VMEM (same decomposition as kernels/vtrace). One fori_loop runs
the recursion entirely in-register.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import interpret_mode, compiler_params


def _kernel(base_ref, coef_ref, init_ref, out_ref, *, T):
    base = base_ref[...]                                   # (T,bb)
    coef = coef_ref[...]
    init = init_ref[...]                                   # (1,bb)

    def step(i, carry):
        acc, out = carry
        t = T - 1 - i
        acc = base[t] + coef[t] * acc
        out = out.at[t].set(acc)
        return acc, out

    _, out = jax.lax.fori_loop(0, T, step,
                               (init[0], jnp.zeros_like(base)))
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("bb",))
def discounted_return_tb(base, coef, init, bb=128):
    """Inputs (T,B) f32 time-major, init (B,); B % bb == 0 (wrapper
    pads). Returns out (T,B) with out_t = base_t + coef_t*out_{t+1}."""
    T, B = base.shape
    nb = B // bb
    spec = pl.BlockSpec((T, bb), lambda ib: (0, ib))
    return pl.pallas_call(
        functools.partial(_kernel, T=T),
        grid=(nb,),
        in_specs=[spec, spec, pl.BlockSpec((1, bb), lambda ib: (0, ib))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((T, B), jnp.float32),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret_mode(),
    )(base, coef, init[None])
