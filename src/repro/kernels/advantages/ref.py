"""Reference advantage estimators via one shared reverse linear scan.

GAE (Schulman et al. 2016) and n-step returns (A3C) are both instances
of the first-order reverse recurrence

    out_t = base_t + coef_t * out_{t+1},      out_T = init

  * n-step return:  base = r_t,      coef = γ (1 − done_t),   init = V(s_T)
  * GAE advantage:  base = δ_t,      coef = γ λ (1 − done_t), init = 0
    with δ_t = r_t + γ (1 − done_t) V_{t+1} − V_t.

These refs are bitwise-identical to the scans that previously lived
inline in `algos/ppo.py` / `algos/a3c.py` (same op sequence, same
constant folding) — the kernel in kernel.py is validated against them.
"""
import jax
import jax.numpy as jnp


def discounted_return_ref(base, coef, init):
    """Reverse scan of `out_t = base_t + coef_t * out_{t+1}`.

    base/coef: (T, B) time-major; init: (B,) terminal carry.
    Returns out (T, B)."""
    def body(acc, xs):
        b, c = xs
        acc = b + c * acc
        return acc, acc

    _, out = jax.lax.scan(body, init, (base, coef), reverse=True)
    return out


def gae_ref(rewards, values, dones, bootstrap, gamma=0.99, lam=0.95):
    """Time-major (T, B). Returns (advantages, returns)."""
    values_tp1 = jnp.concatenate([values[1:], bootstrap[None]], axis=0)
    nonterm = 1.0 - dones.astype(jnp.float32)
    deltas = rewards + gamma * nonterm * values_tp1 - values
    adv = discounted_return_ref(deltas, gamma * lam * nonterm,
                                jnp.zeros_like(bootstrap))
    return adv, adv + values


def nstep_return_ref(rewards, dones, bootstrap, gamma=0.99):
    """Discounted n-step returns R_t = r_t + γ(1−done_t) R_{t+1},
    R_T = bootstrap. Time-major (T, B) -> (T, B)."""
    discounts = gamma * (1.0 - dones.astype(jnp.float32))
    return discounted_return_ref(rewards, discounts, bootstrap)
