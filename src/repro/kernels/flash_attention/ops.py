"""Jit'd public wrapper matching the model's (B,S,KVH,G,D) layout."""
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_hsd


def flash_attention(qg, k, v, *, causal=True, window=0, bq=128, bk=128):
    """qg: (B,S,KVH,G,D); k,v: (B,S,KVH,D). Returns (B,S,KVH,G,D)."""
    B, S, KVH, G, D = qg.shape
    q = qg.transpose(0, 2, 3, 1, 4).reshape(B, KVH * G, S, D)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    pad = (-S) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0)))
    o = flash_attention_hsd(q, kk, vv, causal=causal, window=window,
                            bq=bq, bk=bk,
                            valid_len=S if pad else None)
    o = o[:, :, :S]
    return o.reshape(B, KVH, G, S, D).transpose(0, 3, 1, 2, 4)
