"""Pure-jnp oracle for flash attention (naive softmax, O(S^2) memory)."""
import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,Sq,D); k,v: (B,KVH,Sk,D); GQA by head folding.
    Returns (B,H,Sq,D) float32 math."""
    B, H, Sq, D = q.shape
    KVH, Sk = k.shape[1], k.shape[2]
    G = H // KVH
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * D ** -0.5, kk)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos + (Sk - Sq))
    if window:
        mask = mask & (kpos > qpos + (Sk - Sq) - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)
