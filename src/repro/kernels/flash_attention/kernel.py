"""Pallas-TPU flash attention (causal / sliding-window, GQA-aware).

Grid (B, H, nq, nk); the kv axis is the innermost ("arbitrary") dimension
— online-softmax running stats (m, l, acc) live in VMEM scratch and the
output tile is finalized on the last kv step. BlockSpec tiling keeps the
working set at  bq*D + bk*D (k) + bk*D (v) + bq*bk (scores)  in VMEM;
default bq=bk=128 and D<=256 stays well under 16 MiB. The kv-head
index_map folds GQA (q head h reads kv head h//G) so grouped K/V are
never materialized per-head.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pltpu, interpret_mode, compiler_params

NEG_INF = -1e30


def _kernel(qref, kref, vref, oref, mref, lref, accref, *,
            bq, bk, nk, causal, window, scale, valid_len):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        mref[...] = jnp.full_like(mref, NEG_INF)
        lref[...] = jnp.zeros_like(lref)
        accref[...] = jnp.zeros_like(accref)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    run = True
    if causal:  # skip fully-masked upper-triangle blocks
        run = (ik * bk) <= (iq * bq + bq - 1)
    if window:
        run = jnp.logical_and(run, (ik + 1) * bk - 1
                              > iq * bq - window)
    if valid_len is not None:  # skip blocks entirely past the real tail
        run = jnp.logical_and(run, (ik * bk) < valid_len)

    @pl.when(run)
    def _compute():
        q = qref[0, 0].astype(jnp.float32) * scale
        k = kref[0, 0].astype(jnp.float32)
        v = vref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask = mask & (kpos <= qpos)
        if window:
            mask = mask & (kpos > qpos - window)
        if valid_len is not None:  # zero-padded keys must not be attended
            mask = mask & (kpos < valid_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = mref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        lref[...] = lref[...] * alpha + p.sum(axis=-1)
        accref[...] = accref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        mref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(lref[...], 1e-30)
        oref[0, 0] = (accref[...] / l[:, None]).astype(oref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "valid_len"))
def flash_attention_hsd(q, k, v, *, causal=True, window=0, bq=128, bk=128,
                        valid_len=None):
    """q: (B,H,S,D); k,v: (B,KVH,S,D), S % bq == 0 (wrapper pads).
    `valid_len` (static) masks key positions >= valid_len so a
    zero-padded tail is never attended — required for correctness when
    the wrapper pads a non-causal (or any) input."""
    B, H, S, D = q.shape
    KVH = k.shape[1]
    G = H // KVH
    nq, nk = S // bq, S // bk
    scale = D ** -0.5
    kernel = functools.partial(_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                               window=window, scale=scale,
                               valid_len=valid_len)
    scratch = None
    if pltpu is not None:
        scratch = [pltpu.VMEM((bq,), jnp.float32),
                   pltpu.VMEM((bq,), jnp.float32),
                   pltpu.VMEM((bq, D), jnp.float32)]
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=scratch,
        compiler_params=compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret_mode(),
    )(q, k, v)
    return out
