"""Deterministic synthetic LM data pipeline.

Counter-based (threefry fold-in of the step index) so every worker can
materialize its own shard of any global batch without coordination or
host I/O — the data-pipeline analogue of zero-copy simulation. The
stream is a noisy +1 token walk (90% predictable), so cross-entropy has
a learnable floor well below log(vocab) and training curves are
meaningful.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    p_predictable: float = 0.9

    def batch_at(self, step: int):
        """Full global batch {'tokens': (B, S+1) int32} for `step`."""
        return self.shard_at(step, 0, 1)

    def shard_at(self, step: int, shard: int, n_shards: int):
        """The `shard`-of-`n_shards` slice of the global batch — each data
        worker calls this with its own index (survey §5.4 input locality)."""
        b = self.global_batch // n_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), shard)
        k0, k1, k2 = jax.random.split(key, 3)
        t0 = jax.random.randint(k0, (b, 1), 0, self.vocab)
        rand_step = jax.random.randint(k1, (b, self.seq_len), 0, self.vocab)
        predict = jax.random.uniform(k2, (b, self.seq_len)) \
            < self.p_predictable
        deltas = jnp.where(predict, 1, rand_step)
        tokens = (t0 + jnp.concatenate(
            [jnp.zeros((b, 1), jnp.int32),
             jnp.cumsum(deltas, axis=1)], axis=1)) % self.vocab
        return {"tokens": tokens.astype(jnp.int32)}

    def optimal_ce(self):
        """Entropy floor of the stream (nats/token) — the Bayes loss."""
        import math
        p = self.p_predictable
        q = (1 - p) / self.vocab
        return -(p + q) * math.log(p + q) - (self.vocab - 1) * (
            q * math.log(max(q, 1e-30)))
