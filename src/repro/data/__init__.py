from repro.data.tokens import TokenStream  # noqa: F401
