"""Per-architecture smoke tests: reduced variant (<=4 experts, d<=512,
one super-block) runs a forward AND one train step on CPU; output shapes
and finiteness asserted. Decode consistency vs full forward is also
checked (exact for non-MoE; MoE uses a high capacity factor to remove
capacity-drop discrepancies)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, get_config
from repro.models import build_model
from repro.models.model import ModelOpts
from repro.optim import adamw

ARCHS = [a for a in list_archs() if a != "paper-drl-trunk"]
OPTS = ModelOpts(dtype="float32", remat=False)


def _frontend(cfg, B):
    if cfg.frontend == "vision_stub":
        return 0.1 * jnp.ones((B, cfg.frontend_tokens,
                               cfg.frontend_dim or cfg.d_model))
    if cfg.frontend == "audio_stub":
        return 0.1 * jnp.ones((B, cfg.enc_tokens, cfg.d_model))
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch, rng):
    m = build_model(arch, OPTS, reduced=True)
    cfg = m.cfg
    assert cfg.n_layers <= max(2, len(cfg.layer_pattern))
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    p = m.init(rng)
    B, S = 2, 16
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    logits, aux = m.forward(p, tok, _frontend(cfg, B))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, rng):
    m = build_model(arch, OPTS, reduced=True)
    cfg = m.cfg
    p = m.init(rng)
    opt = adamw(1e-3)
    ostate = opt.init(p)
    batch = {"tokens": jax.random.randint(rng, (2, 17), 0, cfg.vocab)}
    fe = _frontend(cfg, 2)
    if fe is not None:
        batch["frontend"] = fe
    (loss, metrics), grads = jax.value_and_grad(
        m.loss, has_aux=True)(p, batch)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.abs(g)) for g in jax.tree_util.tree_leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0
    p2, _ = opt.apply(p, ostate, grads)
    leaves = jax.tree_util.tree_leaves(p2)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    if cfg.moe:  # remove capacity drops for the equivalence check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build_model(cfg, OPTS)
    p = m.init(rng)
    B, S = 2, 12
    tok = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab)
    fe = _frontend(cfg, B)
    full, _ = m.forward(p, tok, fe)
    lg_pre, cache = m.prefill(p, tok[:, :S], fe)
    assert jnp.allclose(full[:, S - 1], lg_pre[:, 0], atol=2e-4), \
        "prefill last-token logits must equal forward"
    npx = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0
    lg_dec, _ = m.decode_step(p, tok[:, S:S + 1], cache,
                              jnp.int32(S + npx))
    err = float(jnp.max(jnp.abs(full[:, S] - lg_dec[:, 0])))
    assert err < 2e-3, f"decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    from repro.configs.base import SHAPES
    m = build_model(arch, OPTS)
    for name, shape in SHAPES.items():
        specs = m.input_specs(shape)
        assert specs, f"{arch} {name} produced empty specs"
        if shape.mode == "decode":
            assert "cache" in specs and "pos" in specs
