"""End-to-end behaviour tests for the framework."""
import jax
import jax.numpy as jnp
import pytest


def test_lm_training_loss_descends():
    """A reduced model trains toward the stream's entropy floor."""
    from repro.launch.train import train
    out = train("paper-drl-trunk", reduced=True, steps=120, batch=16,
                seq=64, lr=3e-3, log_every=20)
    first = out["history"][0]["ce"]
    last = out["history"][-1]["ce"]
    assert last < first * 0.6, (first, last)
    assert last < 4.0


def test_serving_generates_tokens():
    from repro.launch.serve import serve
    out = serve("smollm-360m", reduced=True, batch=2, prompt_len=16,
                gen_len=6)
    assert out["generated_shape"] == [2, 6]
    assert out["decode_tok_per_s"] > 0


def test_impala_cartpole_learns():
    from repro.core.trainer import Trainer, TrainerConfig
    from repro.envs import CartPole
    env = CartPole()
    cfg = TrainerConfig(algo="impala", iters=80, superstep=20, n_envs=32,
                        unroll=32, policy_lag=1, seed=0, log_every=20)
    _, hist = Trainer(env, cfg).fit()
    assert hist[-1]["episode_return"] > hist[0]["episode_return"], hist


def test_trunk_policy_ppo_update():
    """The assigned-architecture trunk adapter drives a PPO policy
    (survey §2 LLM-actor mapping): sample + log_prob + clipped update."""
    from repro.core.networks import TrunkPolicy
    from repro.core.algos import PPO
    from repro.optim import adamw, clip_by_global_norm
    pol = TrunkPolicy("paper-drl-trunk", n_actions=4, ctx=4)
    params = pol.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    obs = jax.random.randint(key, (12, 4), 0, 64)      # token histories
    a, logp = pol.sample(params, obs, key)
    assert a.shape == (12,) and bool(jnp.all(jnp.isfinite(logp)))
    batch = {"obs": obs, "action": a, "logp": logp,
             "adv": jax.random.normal(key, (12,)),
             "ret": jax.random.normal(key, (12,))}
    algo = PPO(pol)
    opt = clip_by_global_norm(adamw(1e-4), 0.5)
    p2, _, loss = algo.update(params, opt.init(params), batch,
                              key, opt, n_epochs=1, n_minibatch=2)
    assert bool(jnp.isfinite(loss))
    # params actually moved
    d = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(p2)))
    assert d > 0


def test_prioritized_vs_uniform_dqn_both_learn():
    """Ape-X claim (survey §3.1): prioritized replay trains at least as
    well as uniform on a sparse-reward task."""
    from repro.core.trainer import Trainer, TrainerConfig
    from repro.envs import GridWorld
    env = GridWorld(n=4, max_steps=16)
    finals = {}
    for prio in (True, False):
        cfg = TrainerConfig(algo="dqn", iters=60, superstep=10,
                            n_envs=16, unroll=8, log_every=20,
                            algo_kwargs={"prioritized": prio,
                                         "warmup": 5,
                                         "eps_decay_steps": 40,
                                         "target_update": 20})
        _, hist = Trainer(env, cfg).fit()
        finals[prio] = hist[-1]["episode_return"]
    assert finals[True] > -0.01 or finals[True] >= finals[False] - 0.05, \
        finals
