"""End-to-end behaviour tests for the framework."""
import jax
import jax.numpy as jnp
import pytest


def test_lm_training_loss_descends():
    """A reduced model trains toward the stream's entropy floor."""
    from repro.launch.train import train
    out = train("paper-drl-trunk", reduced=True, steps=120, batch=16,
                seq=64, lr=3e-3, log_every=20)
    first = out["history"][0]["ce"]
    last = out["history"][-1]["ce"]
    assert last < first * 0.6, (first, last)
    assert last < 4.0


def test_serving_generates_tokens():
    from repro.launch.serve import serve
    out = serve("smollm-360m", reduced=True, batch=2, prompt_len=16,
                gen_len=6)
    assert out["generated_shape"] == [2, 6]
    assert out["decode_tok_per_s"] > 0


def test_impala_cartpole_learns():
    from repro.envs import CartPole
    from repro.core.networks import MLPPolicy
    from repro.launch.rl_train import run_impala
    env = CartPole()
    pol = MLPPolicy(env.obs_dim, env.n_actions)
    _, hist = run_impala(env, pol, iters=80, n_envs=32, unroll=32,
                         policy_lag=1, seed=0, log_every=20)
    assert hist[-1]["mean_episode_return"] > \
        hist[0]["mean_episode_return"], hist


def test_trunk_policy_ppo_update():
    """The assigned-architecture trunk adapter drives a PPO policy
    (survey §2 LLM-actor mapping): sample + log_prob + clipped update."""
    from repro.core.networks import TrunkPolicy
    from repro.core.algos import PPO
    from repro.optim import adamw, clip_by_global_norm
    pol = TrunkPolicy("paper-drl-trunk", n_actions=4, ctx=4)
    params = pol.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    obs = jax.random.randint(key, (12, 4), 0, 64)      # token histories
    a, logp = pol.sample(params, obs, key)
    assert a.shape == (12,) and bool(jnp.all(jnp.isfinite(logp)))
    batch = {"obs": obs, "action": a, "logp": logp,
             "adv": jax.random.normal(key, (12,)),
             "ret": jax.random.normal(key, (12,))}
    algo = PPO(pol)
    opt = clip_by_global_norm(adamw(1e-4), 0.5)
    p2, _, loss = algo.update(params, opt.init(params), batch,
                              key, opt, n_epochs=1, n_minibatch=2)
    assert bool(jnp.isfinite(loss))
    # params actually moved
    d = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(p2)))
    assert d > 0


def test_prioritized_vs_uniform_dqn_both_learn():
    """Ape-X claim (survey §3.1): prioritized replay trains at least as
    well as uniform on a sparse-reward task."""
    from repro.envs import GridWorld
    from repro.launch.rl_train import run_dqn
    env = GridWorld(n=4, max_steps=16)
    finals = {}
    for prio in (True, False):
        _, hist = run_dqn(env, 250, 16, log_every=50, prioritized=prio)
        finals[prio] = hist[-1]["mean_reward"]
    assert finals[True] > -0.01 or finals[True] >= finals[False] - 0.05, \
        finals
