"""Core DRL engine: V-trace properties (hypothesis), replay invariants,
GAE, algorithm learning sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-fallback

from repro.core.vtrace import vtrace
from repro.core.replay import UniformReplay, PrioritizedReplay
from repro.core.algos.ppo import gae

SETTINGS = dict(max_examples=15, deadline=None)


# --------------------------------------------------------------- vtrace
@given(T=st.integers(2, 20), B=st.integers(1, 4),
       seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_vtrace_onpolicy_equals_nstep_return(T, B, seed):
    """Property (IMPALA paper): when behavior == target policy
    (log_rhos = 0), vs_t reduces to the n-step Bellman target."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    disc = 0.9 * jnp.ones((T, B))
    rew = jax.random.normal(ks[0], (T, B))
    val = jax.random.normal(ks[1], (T, B))
    boot = jax.random.normal(ks[2], (B,))
    vs, _ = vtrace(jnp.zeros((T, B)), disc, rew, val, boot)
    # n-step return: R_t = r_t + γ R_{t+1}, R_T = boot
    ref = [None] * T
    acc = boot
    for t in reversed(range(T)):
        acc = rew[t] + disc[t] * acc
        ref[t] = acc
    np.testing.assert_allclose(vs, jnp.stack(ref), atol=1e-4, rtol=1e-4)


@given(T=st.integers(2, 16), seed=st.integers(0, 1000),
       shift=st.floats(-2.0, 2.0))
@settings(**SETTINGS)
def test_vtrace_clip_keeps_targets_finite(T, seed, shift):
    """ρ clipping: vs/adv stay finite for extreme IS ratios, and in the
    fully-off-policy limit (ρ→0) the correction vanishes: vs == V."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    log_rhos = shift + jax.random.normal(ks[0], (T, 1)) * 3.0
    disc = 0.99 * jnp.ones((T, 1))
    rew = jax.random.normal(ks[1], (T, 1))
    val = jax.random.normal(ks[2], (T, 1))
    boot = jnp.zeros((1,))
    vs, adv = vtrace(log_rhos, disc, rew, val, boot)
    assert bool(jnp.all(jnp.isfinite(vs)))
    assert bool(jnp.all(jnp.isfinite(adv)))
    # ρ -> 0 limit: no trust in the behavior data, targets collapse to V
    vs0, adv0 = vtrace(jnp.full((T, 1), -1e9), disc, rew, val, boot)
    np.testing.assert_allclose(vs0, val, atol=1e-5)
    np.testing.assert_allclose(adv0, 0.0, atol=1e-5)


def test_vtrace_zero_reward_zero_delta():
    T, B = 8, 2
    val = jnp.zeros((T, B))
    vs, adv = vtrace(jnp.zeros((T, B)), 0.9 * jnp.ones((T, B)),
                     jnp.zeros((T, B)), val, jnp.zeros((B,)))
    np.testing.assert_allclose(vs, 0.0)
    np.testing.assert_allclose(adv, 0.0)


# ----------------------------------------------------------------- gae
@given(T=st.integers(2, 12), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_gae_lambda1_equals_mc_advantage(T, seed):
    key = jax.random.PRNGKey(seed)
    rew = jax.random.normal(key, (T, 1))
    val = jax.random.normal(jax.random.fold_in(key, 1), (T, 1))
    boot = jnp.zeros((1,))
    dones = jnp.zeros((T, 1))
    adv, ret = gae(rew, val, dones, boot, gamma=0.9, lam=1.0)
    # λ=1: advantage = discounted MC return - value
    acc = boot
    mc = [None] * T
    for t in reversed(range(T)):
        acc = rew[t] + 0.9 * acc
        mc[t] = acc
    np.testing.assert_allclose(adv, jnp.stack(mc) - val, atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(ret, jnp.stack(mc), atol=1e-4, rtol=1e-3)


# --------------------------------------------------------------- replay
def _example():
    return {"x": jnp.zeros((3,)), "r": jnp.zeros(())}


def test_uniform_replay_ring_semantics(rng):
    rp = UniformReplay(8)
    st_ = rp.init(_example())
    batch = {"x": jnp.arange(12, dtype=jnp.float32)[:, None]
             * jnp.ones((1, 3)), "r": jnp.arange(12, dtype=jnp.float32)}
    st_ = rp.add_batch(st_, batch)
    assert int(st_["size"]) == 8
    # oldest 4 were overwritten: stored r values are 4..11
    assert set(np.asarray(st_["store"]["r"]).tolist()) == set(
        range(4, 12))


@given(n=st.integers(1, 32), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_prioritized_replay_sample_validity(n, seed):
    rp = PrioritizedReplay(64)
    st_ = rp.init(_example())
    key = jax.random.PRNGKey(seed)
    batch = {"x": jax.random.normal(key, (20, 3)),
             "r": jnp.arange(20, dtype=jnp.float32)}
    st_ = rp.add_batch(st_, batch)
    got, idx, w = rp.sample(st_, key, n)
    assert bool(jnp.all(idx < 20)), "must never sample unfilled slots"
    assert bool(jnp.all((w > 0) & (w <= 1.0 + 1e-6)))


def test_prioritized_replay_prefers_high_priority(rng):
    rp = PrioritizedReplay(64, alpha=1.0)
    st_ = rp.init(_example())
    batch = {"x": jnp.zeros((32, 3)), "r": jnp.arange(32.0)}
    st_ = rp.add_batch(st_, batch,
                       priorities=jnp.where(jnp.arange(32) == 7, 100.0,
                                            0.001))
    hits = 0
    for i in range(50):
        _, idx, _ = rp.sample(st_, jax.random.fold_in(rng, i), 1)
        hits += int(idx[0] == 7)
    assert hits > 40, f"high-priority item sampled only {hits}/50"


def test_replay_update_priorities(rng):
    rp = PrioritizedReplay(16)
    st_ = rp.init(_example())
    st_ = rp.add_batch(st_, {"x": jnp.zeros((4, 3)), "r": jnp.zeros(4)})
    st_ = rp.update_priorities(st_, jnp.array([0, 1]),
                               jnp.array([5.0, -3.0]))
    assert float(st_["prio"][0]) == pytest.approx(5.0, abs=1e-4)
    assert float(st_["prio"][1]) == pytest.approx(3.0, abs=1e-4)


def test_replay_ptr_wraparound_both_buffers(rng):
    """Two partial adds that cross the ring boundary: ptr wraps, size
    saturates, and the surviving items are exactly the newest ones."""
    for rp in (UniformReplay(8), PrioritizedReplay(8)):
        st_ = rp.init(_example())
        mk = lambda lo, hi: {"x": jnp.zeros((hi - lo, 3)),
                             "r": jnp.arange(lo, hi, dtype=jnp.float32)}
        st_ = rp.add_batch(st_, mk(0, 5))
        assert int(st_["ptr"]) == 5 and int(st_["size"]) == 5
        st_ = rp.add_batch(st_, mk(5, 10))
        assert int(st_["ptr"]) == 2 and int(st_["size"]) == 8
        got = set(np.asarray(st_["store"]["r"]).tolist())
        assert got == set(range(2, 10)), got


def test_replay_add_batch_larger_than_capacity_is_deterministic():
    """n > capacity used to rely on unspecified duplicate-scatter
    ordering; now only the last `capacity` items are written (ring
    semantics), and priorities ride along."""
    rp = PrioritizedReplay(4)
    st_ = rp.init(_example())
    st_ = rp.add_batch(st_, {"x": jnp.zeros((10, 3)),
                             "r": jnp.arange(10, dtype=jnp.float32)},
                       priorities=jnp.arange(10, dtype=jnp.float32))
    assert int(st_["ptr"]) == 10 % 4 and int(st_["size"]) == 4
    r = np.asarray(st_["store"]["r"])
    assert set(r.tolist()) == {6.0, 7.0, 8.0, 9.0}
    # priority i rode with item i through the truncation
    np.testing.assert_allclose(np.asarray(st_["prio"]), r)


def test_replay_empty_buffer_sampling_documented_behavior(rng):
    """Sampling from an EMPTY buffer is degenerate-but-defined: slot-0
    zeros with finite weights, never NaN (see replay.py docstring)."""
    urp = UniformReplay(8)
    batch, idx = urp.sample(urp.init(_example()), rng, 4)
    assert np.asarray(idx).tolist() == [0, 0, 0, 0]
    np.testing.assert_allclose(batch["x"], 0.0)
    # both paths: every draw lands on slot 0 (the only "valid" one;
    # the fused path's surplus positions repeat the top draw)
    for fused in (False, True):
        prp = PrioritizedReplay(8, fused=fused)
        batch, idx, w = prp.sample(prp.init(_example()), rng, 4)
        assert np.asarray(idx).tolist() == [0, 0, 0, 0], (fused, idx)
        np.testing.assert_allclose(batch["x"], 0.0)
        assert bool(jnp.all(jnp.isfinite(w))), (fused, w)


def test_prioritized_is_weight_normalization(rng):
    """w ∝ (N p_i)^{-β} normalized to max 1; uniform priorities give
    exactly w == 1 for every draw, on both sampling paths."""
    for fused in (False, True):
        rp = PrioritizedReplay(32, fused=fused)
        st_ = rp.init(_example())
        st_ = rp.add_batch(st_, {"x": jnp.zeros((16, 3)),
                                 "r": jnp.zeros(16)},
                           priorities=jnp.ones((16,)))
        _, idx, w = rp.sample(st_, rng, 8)
        assert bool(jnp.all(idx < 16)), fused
        np.testing.assert_allclose(w, 1.0, atol=1e-5,
                                   err_msg=f"fused={fused}")


def test_priority_update_roundtrip_steers_sampling(rng):
    """update_priorities -> sample round-trip: after reassigning all
    mass to one slot, (α=1) sampling concentrates there — on the
    legacy path and the fused Gumbel-top-k path alike."""
    for fused in (False, True):
        rp = PrioritizedReplay(64, alpha=1.0, fused=fused)
        st_ = rp.init(_example())
        st_ = rp.add_batch(st_, {"x": jnp.zeros((32, 3)),
                                 "r": jnp.arange(32.0)})
        st_ = rp.update_priorities(
            st_, jnp.arange(32),
            jnp.where(jnp.arange(32) == 11, 1e4, 1e-4))
        hits = 0
        for i in range(30):
            _, idx, _ = rp.sample(st_, jax.random.fold_in(rng, i), 1)
            hits += int(idx[0] == 11)
        assert hits > 24, (fused, hits)


def test_prioritized_legacy_weights_match_softmax_formula(rng):
    """The softmax-free legacy path is BITWISE the old full-capacity
    softmax materialization (gather commutes with the normalize)."""
    rp = PrioritizedReplay(64)
    st_ = rp.init(_example())
    st_ = rp.add_batch(st_, {"x": jax.random.normal(rng, (20, 3)),
                             "r": jnp.arange(20.0)})
    _, idx, w = rp.sample(st_, rng, 16)
    valid = jnp.arange(64) < st_["size"]
    logits = jnp.where(valid, rp.alpha * jnp.log(st_["prio"] + rp.eps),
                       -jnp.inf)
    probs = jax.nn.softmax(logits)
    w_old = (st_["size"] * probs[idx] + 1e-12) ** (-rp.beta)
    w_old = w_old / jnp.maximum(w_old.max(), 1e-12)
    assert np.array_equal(np.asarray(w), np.asarray(w_old))


def test_prioritized_fused_prefers_high_priority(rng):
    rp = PrioritizedReplay(64, alpha=1.0, fused=True)
    st_ = rp.init(_example())
    st_ = rp.add_batch(st_, {"x": jnp.zeros((32, 3)),
                             "r": jnp.arange(32.0)},
                       priorities=jnp.where(jnp.arange(32) == 7, 100.0,
                                            0.001))
    hits = 0
    for i in range(50):
        _, idx, _ = rp.sample(st_, jax.random.fold_in(rng, i), 1)
        hits += int(idx[0] == 7)
    assert hits > 40, f"high-priority item sampled only {hits}/50"


# Learning-sanity integration tests live in tests/test_trainer.py (they
# run through the unified Agent/Trainer API and need no hypothesis).
