"""Policy serving subsystem (repro.core.serving + launch/serve_policy):
ParamStore template/versioning units, bucket-grammar units, batcher
FIFO fairness + never-dropping, the bucket-parity pin (padded
bucket-of-B response bitwise equals per-request eval, for every
registered env spec), zero-recompile hot-swap (compile-counter pinned),
the checkpoint round trip (Trainer fit -> repro.checkpoint save ->
ParamStore.load -> serve_step bitwise the live TrainState's
actor_policy, all four algorithms), and the CLI contract for
--load/--buckets."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.envs as envs
from repro.checkpoint import save_checkpoint
from repro.core.networks import MLPPolicy
from repro.core.serving import (ParamStore, RequestBatcher, ServeEngine,
                                bucket_for, validate_buckets)
from repro.core.trainer import Trainer, TrainerConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
ALGOS = ("a3c", "dqn", "impala", "ppo")


def _mlp_engine(env_name="cartpole", buckets=(8,), seed=3, hidden=(16,)):
    env = envs.make(env_name)
    policy = MLPPolicy.for_spec(env.spec, hidden=hidden)
    store = ParamStore()
    store.publish(policy.init(jax.random.PRNGKey(0)))
    return env, ServeEngine(policy, env.spec.observation,
                            buckets=buckets, store=store, seed=seed)


def _obs_rows(env, n, seed=7):
    return jax.vmap(env.spec.observation.sample)(
        jax.random.split(jax.random.PRNGKey(seed), n))


# ------------------------------------------------------------ ParamStore
def test_param_store_versions_are_monotonic():
    store = ParamStore()
    assert store.version == 0
    p = {"w": jnp.ones((2, 2))}
    assert store.publish(p) == 1
    assert store.publish(p) == 2
    v, got = store.get()
    assert v == 2
    np.testing.assert_array_equal(got["w"], p["w"])


def test_param_store_empty_get_raises():
    with pytest.raises(RuntimeError, match="publish"):
        ParamStore().get()


def test_param_store_rejects_shape_and_tree_drift():
    store = ParamStore()
    store.publish({"w": jnp.ones((2, 2)), "b": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="recompile"):
        store.publish({"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="treedef"):
        store.publish({"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError, match="'w'"):
        store.publish({"w": jnp.ones((2, 2), jnp.int32),
                       "b": jnp.zeros((2,))})
    # the failed publishes never became versions
    assert store.version == 1


def test_in_flight_snapshot_survives_publish():
    """A dispatch reads (version, params) once; publishing mid-flight
    must not change what the snapshot computes — params are immutable
    traced inputs, pinned here bitwise."""
    env, engine = _mlp_engine()
    obs = _obs_rows(env, 3)
    v1, p1 = engine.store.get()
    before = engine.eval_bucket(list(obs), [0, 1, 2], 8, params=p1)
    engine.store.publish(jax.tree_util.tree_map(lambda a: a * 2.0, p1))
    after = engine.eval_bucket(list(obs), [0, 1, 2], 8, params=p1)
    for a, b in zip(before, after):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert engine.store.version == v1 + 1


# -------------------------------------------------------- bucket grammar
def test_bucket_for_picks_smallest_fitting_bucket():
    assert bucket_for(1, (4, 16)) == 4
    assert bucket_for(4, (4, 16)) == 4
    assert bucket_for(5, (4, 16)) == 16
    assert bucket_for(16, (4, 16)) == 16
    with pytest.raises(ValueError, match="largest bucket"):
        bucket_for(17, (4, 16))
    with pytest.raises(ValueError, match="empty"):
        bucket_for(0, (4, 16))


def test_validate_buckets_rejects_bad_grammars():
    assert validate_buckets((1, 4, 16)) == (1, 4, 16)
    for bad, frag in [((), "at least one"), ((0,), "positive"),
                      ((4, 4), "increasing"), ((8, 2), "increasing")]:
        with pytest.raises(ValueError, match=frag):
            validate_buckets(bad)


# ------------------------------------------------------- RequestBatcher
def test_batcher_fifo_and_never_drops():
    """37 requests through a cap-8 take loop: every id answered exactly
    once, in submission order — backpressure queues, never drops."""
    b = RequestBatcher()
    ids = [b.submit(i) for i in range(37)]
    assert ids == list(range(37))
    seen = []
    while len(b):
        chunk = b.take(8)
        assert len(chunk) <= 8
        seen.extend(r["id"] for r in chunk)
    assert seen == ids  # FIFO, all 37, no duplicates


def test_batcher_take_respects_arrival_times():
    b = RequestBatcher()
    b.submit("a", arrival=1.0)
    b.submit("b", arrival=5.0)
    b.submit("c", arrival=2.0)  # behind b: FIFO order, not arrival sort
    assert [r["obs"] for r in b.take(8, now=0.5)] == []
    assert [r["obs"] for r in b.take(8, now=1.5)] == ["a"]
    # "c" has arrived but FIFO means the not-yet-arrived "b" blocks it
    assert [r["obs"] for r in b.take(8, now=2.5)] == []
    assert [r["obs"] for r in b.take(8, now=6.0)] == ["b", "c"]
    assert len(b) == 0


def test_engine_fifo_fairness_under_bucketed_dispatch():
    """End-to-end: responses complete in submission order and every
    request is answered exactly once, whatever micro-batch splits the
    bucket grammar produces."""
    env, engine = _mlp_engine(buckets=(2, 4))
    obs = _obs_rows(env, 11)
    ids = [engine.submit(o) for o in obs]
    order = [r["id"] for r in engine.drain()]
    assert order == ids
    assert sorted(engine.results) == ids


# ------------------------------------------------------- bucket parity
@pytest.mark.parametrize("name", envs.available())
def test_bucket_parity_per_request_bitwise(name):
    """The pad-to-bucket pin, per registered env spec: row i of a
    padded bucket-of-B dispatch is bitwise row i of a per-request
    (single-request, same-bucket) dispatch — a response never depends
    on which other requests shared the micro-batch."""
    env = envs.make(name)
    policy = MLPPolicy.for_spec(env.spec, hidden=(16,))
    store = ParamStore()
    store.publish(policy.init(jax.random.PRNGKey(0)))
    engine = ServeEngine(policy, env.spec.observation, buckets=(8,),
                         store=store, seed=3)
    obs = _obs_rows(env, 6)
    a_b, l_b, v_b = engine.eval_bucket(list(obs), list(range(6)), 8)
    for i in range(6):
        a_1, l_1, v_1 = engine.eval_bucket([obs[i]], [i], 8)
        np.testing.assert_array_equal(np.asarray(a_b[i]),
                                      np.asarray(a_1[0]))
        np.testing.assert_array_equal(np.asarray(l_b[i]),
                                      np.asarray(l_1[0]))
        np.testing.assert_array_equal(np.asarray(v_b[i]),
                                      np.asarray(v_1[0]))


# -------------------------------------------- zero-recompile hot swap
def test_hot_swap_and_batch_size_variation_never_recompile():
    """After warmup, serving arbitrary batch sizes and hot-swapping
    params leaves the compile counter flat — pad-to-bucket keeps shapes
    static and params are traced inputs."""
    env, engine = _mlp_engine(buckets=(2, 4))
    assert engine.warmup() == 2          # one compile per bucket
    c0 = engine.compile_count
    obs = _obs_rows(env, 9)
    for n in (1, 2, 3, 4):               # both buckets, varying n_valid
        for o in obs[:n]:
            engine.submit(o)
        engine.drain()
    assert engine.compile_count == c0
    _, p1 = engine.store.get()
    out1 = engine.eval_bucket(list(obs[:3]), [0, 1, 2], 4)
    # hot-swap: same shapes, new values -> new outputs, zero compiles
    engine.store.publish(
        jax.tree_util.tree_map(lambda a: a * 1.5, p1))
    out2 = engine.eval_bucket(list(obs[:3]), [0, 1, 2], 4)
    assert engine.compile_count == c0
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(out1[1:], out2[1:]))  # logp/value moved


def test_responses_are_tagged_with_dispatch_version():
    env, engine = _mlp_engine(buckets=(4,))
    engine.warmup()
    obs = _obs_rows(env, 4)
    engine.submit(obs[0])
    (r1,) = engine.step()
    _, p = engine.store.get()
    v2 = engine.store.publish(jax.tree_util.tree_map(
        lambda a: a + 1e-3, p))
    engine.submit(obs[1])
    (r2,) = engine.step()
    assert r1["version"] == v2 - 1
    assert r2["version"] == v2


# --------------------------------------------- checkpoint round trip
@pytest.mark.parametrize("algo", ALGOS)
def test_checkpoint_roundtrip_bitwise(algo, tmp_path):
    """Trainer fit -> checkpoint save -> ParamStore.load ->
    serve_step == serving agent.actor_policy on the live TrainState,
    bitwise, for every algorithm (for DQN that includes the annealed
    exploration rate riding the restored step counter)."""
    env = envs.make("cartpole")
    kw = {"hidden": (16,)}
    if algo == "dqn":
        kw["replay_capacity"] = 512
    cfg = TrainerConfig(algo=algo, iters=4, superstep=2, n_envs=8,
                        unroll=8, seed=0, log_every=2, algo_kwargs=kw)
    trainer = Trainer(env, cfg)
    state, _ = trainer.fit()
    path = save_checkpoint(str(tmp_path / f"{algo}.npz"), state)

    live = ParamStore()
    live.publish_from_state(trainer.agent, state)
    restored = ParamStore()
    restored.load_checkpoint(path, trainer.agent)

    obs = _obs_rows(env, 5)
    outs = []
    for store in (live, restored):
        engine = ServeEngine(trainer.agent.policy, env.spec.observation,
                             buckets=(8,), store=store, seed=11)
        outs.append(engine.eval_bucket(list(obs), list(range(5)), 8))
    for a, b in zip(*outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------- ZeRO-3 checkpoint/serve round trip
_ZERO3_SERVE_SCRIPT = """
import json, os
import jax, numpy as np
import repro.envs as envs
from repro.checkpoint import save_checkpoint
from repro.checkpoint.ckpt import load_train_state
from repro.core import agent as agent_api
from repro.core.distribution import DistPlan
from repro.core.serving import ParamStore, ServeEngine
from repro.core.topology import ZeRO3Agent
from repro.core.trainer import Trainer, TrainerConfig

env = envs.make("cartpole")
kw = {"hidden": (16,)}
cfg = TrainerConfig(algo="impala", iters=4, superstep=2, n_envs=8,
                    unroll=8, plan=DistPlan.zero3(2, 2), seed=0,
                    log_every=2, algo_kwargs=kw)
trainer = Trainer(env, cfg)
state, _ = trainer.fit()
path = save_checkpoint(os.environ["CKPT_PATH"], state)

# live: the trainer's agent is still the ZeRO3Agent wrapper — the
# reassembled fit state must publish through host_state unchanged
live = ParamStore()
live.publish_from_state(trainer.agent, state)

# restored (plain): a fresh unwrapped serving agent reads the
# plan-independent archive directly
plain = agent_api.make("impala", env, **kw)
restored = ParamStore()
restored.load_checkpoint(path, plain)

# restored (wrapped): load_train_state reassembles the wrapper-form
# init template via host_state before matching the archive
wrapped = ZeRO3Agent(agent_api.make("impala", env, **kw), "shard", 2)
st_w, step_w = load_train_state(path, wrapped)
via_wrapper = ParamStore()
via_wrapper.publish(wrapped.inner.actor_policy(st_w, 0))

obs = jax.vmap(env.spec.observation.sample)(
    jax.random.split(jax.random.PRNGKey(7), 5))
outs = []
for store in (live, restored, via_wrapper):
    engine = ServeEngine(trainer.agent.policy, env.spec.observation,
                         buckets=(8,), store=store, seed=11)
    outs.append([np.asarray(x).tolist()
                 for x in engine.eval_bucket(list(obs),
                                             list(range(5)), 8)])
print("RESULT " + json.dumps({
    "plain_bitwise": outs[0] == outs[1],
    "wrapped_bitwise": outs[0] == outs[2],
    "step": step_w}))
"""


def test_zero3_checkpoint_serve_round_trip_bitwise(tmp_path):
    """Satellite 4 acceptance: fit under a zero3-role plan -> save ->
    restore into (a) a plain serving agent and (b) a ZeRO3Agent-wrapped
    one -> serve at a fixed bucket bitwise-matches publishing the live
    fit state. Checkpoints stay plan-independent; the wrapper's
    host_state makes both templates line up."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC,
               CKPT_PATH=str(tmp_path / "zero3_impala.npz"))
    r = subprocess.run([sys.executable, "-c", _ZERO3_SERVE_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["plain_bitwise"], out
    assert out["wrapped_bitwise"], out


# ------------------- layer-wise ZeRO-3 checkpoint/serve round trip
_ZERO3_LAYERWISE_SERVE_SCRIPT = """
import json, os
import jax, numpy as np
import repro.envs as envs
from repro.checkpoint import save_checkpoint
from repro.checkpoint.ckpt import load_train_state
from repro.core import agent as agent_api
from repro.core.distribution import DistPlan
from repro.core.serving import ParamStore, ServeEngine
from repro.core.topology import ZeRO3Agent
from repro.core.trainer import Trainer, TrainerConfig

env = envs.make("cartpole")
kw = {"policy": "trunk", "trunk_kwargs": {"reduced": True}}
cfg = TrainerConfig(algo="impala", iters=4, superstep=2, n_envs=8,
                    unroll=6, plan=DistPlan.zero3(2, 2), seed=0,
                    log_every=2, algo_kwargs=kw)
trainer = Trainer(env, cfg)
state, _ = trainer.fit()
assert trainer.partition["listwise"], trainer.partition
path = save_checkpoint(os.environ["CKPT_PATH"], state)

# live: fit() reassembles the layer-wise chunk lists back into the
# plan-independent tree — publish it straight through host_state
live = ParamStore()
live.publish_from_state(trainer.agent, state)

# restored (plain): a fresh unwrapped serving agent reads the archive
plain = agent_api.make("impala", env, **kw)
restored = ParamStore()
restored.load_checkpoint(path, plain)
stores = [live, restored]

# restored (re-sharded): the SAME archive loads into wrappers at the
# original 2 shards AND a different shard count — per-block chunk
# geometry is recomputed from the template, never persisted
for n in (2, 4):
    wrapped = ZeRO3Agent(agent_api.make("impala", env, **kw),
                         "shard", n)
    st_w, _ = load_train_state(path, wrapped)
    ps = ParamStore()
    ps.publish(wrapped.inner.actor_policy(st_w, 0))
    stores.append(ps)

obs = jax.vmap(env.spec.observation.sample)(
    jax.random.split(jax.random.PRNGKey(7), 5))
outs = []
for store in stores:
    engine = ServeEngine(trainer.agent.policy, env.spec.observation,
                         buckets=(8,), store=store, seed=11)
    outs.append([np.asarray(x).tolist()
                 for x in engine.eval_bucket(list(obs),
                                             list(range(5)), 8)])
print("RESULT " + json.dumps({
    "plain_bitwise": outs[0] == outs[1],
    "reshard2_bitwise": outs[0] == outs[2],
    "reshard4_bitwise": outs[0] == outs[3]}))
"""


@pytest.mark.slow
def test_zero3_layerwise_checkpoint_serve_round_trip_bitwise(tmp_path):
    """Satellite 4 acceptance (PR 10): fit the transformer trunk under
    the layer-wise zero3 plan (per-block chunk lists) -> host_state ->
    save -> restore into a plain serving agent AND into ZeRO3Agent
    wrappers at the original and at a different shard count -> serve at
    a fixed bucket, all bitwise vs publishing the live fit state. The
    checkpoint stays plan-independent; layer-wise geometry is derived
    from the template on load, never serialized."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC,
               CKPT_PATH=str(tmp_path / "zero3_lw_trunk.npz"))
    r = subprocess.run([sys.executable, "-c",
                        _ZERO3_LAYERWISE_SERVE_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["plain_bitwise"], out
    assert out["reshard2_bitwise"], out
    assert out["reshard4_bitwise"], out


# --------------------------------------------------------- CLI contract
def test_cli_load_buckets_contract(tmp_path):
    """serve_policy honors --load/--buckets, reports the zero-recompile
    pin, and (always) writes a schema-valid BENCH_serve.json with one
    row per load x bucket-config cell — into --out, so the committed
    repo-root full-run file is never clobbered by a suite run."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_policy", "--quick",
         "--algo", "ppo", "--load", "400,1600", "--buckets", "2,8;8",
         "--requests", "80", "--train-iters", "2",
         "--out", str(tmp_path)],
        capture_output=True, text=True, cwd=REPO_ROOT,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["loads"] == [400.0, 1600.0]
    assert out["bucket_configs"] == [[2, 8], [8]]
    assert out["recompiles_after_warmup"] == 0
    assert out["warmup_compiles"] == 3    # 2 buckets + 1 bucket
    assert out["hot_swaps"] == 4          # one per cell
    assert len(out["cells"]) == 4
    for cell in out["cells"]:
        assert cell["n"] == 80
        assert cell["p99_ms"] > cell["p50_ms"] > 0
        assert cell["versions"] >= 2      # the mid-cell hot swap served
    doc = json.load(open(os.path.join(str(tmp_path), "BENCH_serve.json")))
    sys.path.insert(0, REPO_ROOT)
    from benchmarks.common import validate_bench_json
    validate_bench_json(doc)
    names = [row["name"] for row in doc["rows"]]
    assert "serve/compile_flat" in names
    assert sum(1 for n in names if "/load" in n) == 4


def test_cli_rejects_malformed_load_and_buckets():
    for flags in (["--load", "0"], ["--load", "abc"],
                  ["--buckets", "4,2"], ["--buckets", ";"],
                  ["--buckets", "x,y"]):
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve_policy"] + flags,
            capture_output=True, text=True, cwd=REPO_ROOT,
            env=dict(os.environ, PYTHONPATH=SRC), timeout=120)
        assert r.returncode != 0, flags
        assert "usage" in r.stderr or "error" in r.stderr, flags


def test_serve_front_door_delegates_policy_subcommand(tmp_path):
    """launch/serve.py is the one front door: `serve policy ...` runs
    the policy-serving launcher (flags forwarded verbatim, including
    --out so the committed BENCH_serve.json stays untouched)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "policy",
         "--quick", "--requests", "40", "--train-iters", "0",
         "--out", str(tmp_path)],
        capture_output=True, text=True, cwd=REPO_ROOT,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert (tmp_path / "BENCH_serve.json").exists()
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # --quick defaults: loads 500,2000 over bucket configs (4,16);(16)
    assert out["bucket_configs"] == [[4, 16], [16]]
    assert out["loads"] == [500.0, 2000.0]
    assert out["recompiles_after_warmup"] == 0
