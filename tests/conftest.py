# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# ONE device; multi-device tests spawn subprocesses with their own flags.
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)

# Optional-hypothesis fallback (see requirements-dev.txt): when
# hypothesis is absent, @given property tests skip instead of aborting
# the whole collection, and plain tests in the same module still run.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
