# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# ONE device; multi-device tests spawn subprocesses with their own flags.
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
