"""Decoupled actor-learner pipeline (repro.core.pipeline + the
Trainer's ``pipeline=`` mode): queue-op unit tests (capacity-1 ring,
wraparound past capacity, guarded pop-on-empty/push-on-full), the
sync-discipline -> queue-depth mapping, the depth-0 bitwise-parity
matrix vs the fused path for all four algorithms on a 4-device mesh,
chunked-vs-one-shot fit parity, the elastic-actors guard, the CLI
contract, and HostPipelined composability (the deliberately queue-free
Fig. 5a baseline)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distribution import AxisSpec, DistPlan
from repro.core.pipeline import (queue_capacity, queue_init, queue_pop,
                                 queue_push, queue_size)
from repro.core.sync import SyncConfig, pipeline_depth
from repro.core.trainer import Trainer, TrainerConfig
from repro.envs import CartPole
from repro.envs.host_env import HostPipelined

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ALGOS = ("a3c", "dqn", "impala", "ppo")


def _item(i):
    """A small two-leaf trajectory stand-in, value-tagged by `i`
    (works for Python ints and traced scalars alike)."""
    i = jnp.asarray(i, jnp.int32)
    return {"x": jnp.full((3, 2), i.astype(jnp.float32)),
            "n": i}


# ------------------------------------------------------ queue op units
def test_queue_init_shapes_capacity_and_emptiness():
    q = queue_init(_item(0), 4)
    assert queue_capacity(q) == 4
    assert int(queue_size(q)) == 0
    assert q["buf"]["x"].shape == (4, 3, 2)
    assert q["buf"]["n"].shape == (4,)
    assert q["buf"]["n"].dtype == jnp.int32


def test_queue_init_rejects_nonpositive_capacity():
    with pytest.raises(ValueError, match="capacity"):
        queue_init(_item(0), 0)


def test_queue_capacity1_ring_roundtrip():
    """The depth<=1 workhorse: one slot, push/pop alternation, both
    guards exercised. Push-on-full REFUSES (never overwrites); pop-on-
    empty returns the stale slot with ok=False and moves nothing."""
    q = queue_init(_item(0), 1)
    q, ok = queue_push(q, _item(7))
    assert bool(ok) and int(queue_size(q)) == 1
    # full: the second push is refused, slot keeps the first item
    q, ok = queue_push(q, _item(8))
    assert not bool(ok) and int(queue_size(q)) == 1
    np.testing.assert_array_equal(q["buf"]["x"][0], np.full((3, 2), 7.0))
    q, item, ok = queue_pop(q)
    assert bool(ok) and int(item["n"]) == 7
    assert int(queue_size(q)) == 0
    # empty: pop is a guarded no-op returning the stale head slot
    q2, stale, ok = queue_pop(q)
    assert not bool(ok) and int(stale["n"]) == 7
    assert int(queue_size(q2)) == 0
    assert int(q2["head"]) == int(q["head"])


def test_queue_wraparound_is_fifo_past_capacity():
    """More pushes than capacity: the monotonic counters wrap the slot
    index (slot = counter % capacity) and FIFO order survives."""
    q = queue_init(_item(0), 2)
    popped = []
    q, _ = queue_push(q, _item(0))
    q, _ = queue_push(q, _item(1))
    for i in range(2, 6):  # 6 total pushes through a 2-slot ring
        q, item, ok = queue_pop(q)
        assert bool(ok)
        popped.append(int(item["n"]))
        q, ok = queue_push(q, _item(i))
        assert bool(ok)
    q, item, _ = queue_pop(q)
    popped.append(int(item["n"]))
    q, item, _ = queue_pop(q)
    popped.append(int(item["n"]))
    assert popped == [0, 1, 2, 3, 4, 5]
    assert int(q["head"]) == int(q["tail"]) == 6  # counters never reset


def test_queue_ops_compose_under_scan():
    """Total functions: a jitted lax.scan alternating pop-then-push
    (the depth>=1 tick order) keeps the item stream exact."""
    q = queue_init(_item(0), 3)
    q, _ = queue_push(q, _item(0))
    q, _ = queue_push(q, _item(1))

    def tick(q, i):
        q, item, ok = queue_pop(q)
        q, _ = queue_push(q, _item(i + 2))
        return q, (item["n"], ok)

    @jax.jit
    def run(q):
        return jax.lax.scan(tick, q, jnp.arange(8))

    q, (ns, oks) = run(q)
    np.testing.assert_array_equal(ns, np.arange(8))
    assert bool(oks.all())
    assert int(queue_size(q)) == 2  # steady state: depth items in flight


# ------------------------------------------------- sync -> depth mapping
def test_sync_pipeline_depth_mapping():
    assert pipeline_depth(SyncConfig("bsp", max_delay=9)) == 0
    assert pipeline_depth(SyncConfig("asp", max_delay=3)) == 3
    assert pipeline_depth(SyncConfig("ssp", max_delay=4,
                                     staleness_bound=2)) == 2
    # ssp never exceeds the asp worst case it is a bounded form of
    assert pipeline_depth(SyncConfig("ssp", max_delay=1,
                                     staleness_bound=5)) == 1
    with pytest.raises(ValueError):
        pipeline_depth(SyncConfig("yolo"))


def test_plan_pipeline_depth_sums_over_axes():
    assert DistPlan.flat(4).pipeline_depth == 0  # bsp default
    assert DistPlan.flat(2, sync="ssp", staleness_bound=2,
                         max_delay=4).pipeline_depth == 2
    assert DistPlan.flat(2, sync="asp", max_delay=3).pipeline_depth == 3
    two = DistPlan(axes=(
        AxisSpec("hosts", 2, sync="ssp", staleness_bound=1, max_delay=4),
        AxisSpec("workers", 2, sync="asp", max_delay=2)))
    assert [ax.pipeline_depth for ax in two.axes] == [1, 2]
    assert two.pipeline_depth == 3  # staleness budgets add across levels


def test_trainer_resolves_depth_and_capacity():
    env = CartPole()
    ssp = DistPlan.flat(1, sync="ssp", staleness_bound=2, max_delay=2)

    def mk(pipeline, plan=None):
        return Trainer(env, TrainerConfig(
            algo="impala", iters=2, superstep=2, n_envs=4, unroll=4,
            plan=plan, pipeline=pipeline, algo_kwargs={"hidden": (8,)}))

    off = mk(False, ssp)
    assert off.pipeline_depth == 0 and off.pipeline_capacity is None
    bsp = mk(True)  # default plan is bsp -> lockstep, 1-slot ring
    assert bsp.pipeline_depth == 0 and bsp.pipeline_capacity == 1
    deep = mk(True, ssp)
    assert deep.pipeline_depth == 2 and deep.pipeline_capacity == 2
    # the allocated queue honors the capacity and starts empty
    state, sim, _ = deep._init_all()
    q = deep._init_queue(state, sim)
    assert queue_capacity(q) == 2 and int(queue_size(q)) == 0
    # the producer program fills it to steady state (depth items)
    sim, q = deep._producer_program(2)(
        state, sim, q, jnp.arange(2, dtype=jnp.int32),
        jnp.zeros((2,), jnp.int32))
    assert int(queue_size(q)) == 2


def test_pipeline_rejects_varying_actor_schedule():
    """The queue's item shape is fixed at compile time, so elastic
    actor resharding cannot ride a pipelined fit; constant schedules
    (a no-op reshard) stay allowed."""
    env = CartPole()
    with pytest.raises(ValueError, match="actor"):
        Trainer(env, TrainerConfig(
            algo="impala", iters=4, superstep=2, n_envs=8, unroll=4,
            plan=DistPlan.flat(1, actors=(8, 4)), pipeline=True,
            algo_kwargs={"hidden": (8,)}))
    Trainer(env, TrainerConfig(  # constant schedule: fine
        algo="impala", iters=4, superstep=2, n_envs=8, unroll=4,
        plan=DistPlan.flat(1, actors=(8,)), pipeline=True,
        algo_kwargs={"hidden": (8,)}))


# ---------------- depth-0 bitwise parity matrix (4 fake devices) + ssp
_PIPE_PARITY_SCRIPT = textwrap.dedent("""
    import json, math
    import jax, numpy as np
    import repro.envs as envs
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig

    env = envs.make("cartpole")
    KW = {"a3c": {"hidden": (8,)}, "impala": {"hidden": (8,)},
          "ppo": {"hidden": (8,)},
          "dqn": {"hidden": (8,), "replay_capacity": 512, "warmup": 1}}

    def fit(algo, plan, pipeline):
        cfg = TrainerConfig(algo=algo, iters=4, superstep=2, n_envs=8,
                            unroll=6, plan=plan, log_every=1, seed=0,
                            pipeline=pipeline, algo_kwargs=KW[algo])
        return Trainer(env, cfg).fit()

    def eq(a, b):
        a, b = np.asarray(a), np.asarray(b)
        return (a.shape == b.shape and a.dtype == b.dtype
                and bool(np.array_equal(a, b, equal_nan=True)))

    def bitwise(t1, t2):
        l1 = jax.tree_util.tree_leaves(t1)
        l2 = jax.tree_util.tree_leaves(t2)
        return len(l1) == len(l2) and all(eq(a, b)
                                          for a, b in zip(l1, l2))

    def hist_eq(h1, h2):
        return len(h1) == len(h2) and all(
            r1.keys() == r2.keys() and all(
                np.array_equal(np.float64(r1[k]), np.float64(r2[k]),
                               equal_nan=True) for k in r1)
            for r1, r2 in zip(h1, h2))

    out = {}
    for algo in ("a3c", "dqn", "impala", "ppo"):
        # depth 0 (bsp, 4 workers): pipelined must be bitwise the fused
        # lockstep program — params, actor ring AND metric history
        s_f, h_f = fit(algo, DistPlan.flat(4), pipeline=False)
        s_p, h_p = fit(algo, DistPlan.flat(4), pipeline=True)
        # depth 1 (ssp): genuinely overlapped — just pin it trains
        ssp = DistPlan.flat(4, sync="ssp", staleness_bound=1,
                            max_delay=1)
        _, h_s = fit(algo, ssp, pipeline=True)
        out[algo] = {
            "d0_params": bitwise(s_f.params, s_p.params),
            "d0_ring": bitwise(s_f.ring, s_p.ring),
            "d0_hist": hist_eq(h_f, h_p),
            "ssp_finite": all(math.isfinite(r["loss"]) for r in h_s)}
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def pipe_parity_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _PIPE_PARITY_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("algo", ALGOS)
def test_pipelined_depth0_bitwise_fused(pipe_parity_results, algo):
    """Acceptance: under a bsp plan the pipelined fit (producer ->
    1-slot queue -> consumer, compiled to lockstep) is f32-bitwise the
    fused superstep — params, actor-param ring, and history — for all
    four algorithms on a 4-device mesh."""
    res = pipe_parity_results[algo]
    for key in ("d0_params", "d0_ring", "d0_hist"):
        assert res[key], (algo, key, res)


@pytest.mark.parametrize("algo", ALGOS)
def test_pipelined_ssp_trains_finite(pipe_parity_results, algo):
    """Depth 1 (ssp bound): the genuinely-overlapped pipeline trains
    with finite losses for every algorithm."""
    assert pipe_parity_results[algo]["ssp_finite"]


# ----------------------------------- chunked-vs-one-shot fit parity
def _hist_equal(h1, h2):
    if len(h1) != len(h2):
        return False
    for r1, r2 in zip(h1, h2):
        if r1.keys() != r2.keys():
            return False
        for k in r1:
            if not np.array_equal(np.float64(r1[k]), np.float64(r2[k]),
                                  equal_nan=True):
                return False
    return True


def _chunk_pair(algo, pipeline, plan=None, seed=0):
    """(two k=2 dispatches, one k=4 dispatch) of the same 4 iterations."""
    env = CartPole()
    kw = {"hidden": (8,)}
    if algo == "dqn":
        kw.update(replay_capacity=256, warmup=1)

    def run(superstep):
        cfg = TrainerConfig(algo=algo, iters=4, superstep=superstep,
                            n_envs=8, unroll=6, plan=plan, log_every=1,
                            seed=seed, pipeline=pipeline, algo_kwargs=kw)
        return Trainer(env, cfg).fit()

    return run(2), run(4)


def _assert_bitwise(s1, s2):
    for a, b in zip(jax.tree_util.tree_leaves((s1.params, s1.ring)),
                    jax.tree_util.tree_leaves((s2.params, s2.ring))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("algo", ("impala", "dqn"))
def test_chunked_fit_bitwise_fused(algo):
    """Two k=2 supersteps == one k=4 superstep, bitwise, on the fused
    path: the dispatch boundary is invisible to the numerics."""
    (s2, h2), (s4, h4) = _chunk_pair(algo, pipeline=False)
    _assert_bitwise(s2, s4)
    assert _hist_equal(h2, h4)


@pytest.mark.parametrize("algo", ("impala", "ppo"))
def test_chunked_fit_bitwise_pipelined_lockstep(algo):
    """Pipelined bsp (depth 0) keeps the same chunk invariance bitwise:
    lockstep compiles to the fused program, dispatch boundaries and the
    queue included."""
    (s2, h2), (s4, h4) = _chunk_pair(algo, pipeline=True)
    _assert_bitwise(s2, s4)
    assert _hist_equal(h2, h4)


def test_chunked_fit_parity_pipelined_depth1():
    """Depth >= 1: the queue persists across dispatches (no drain), so
    chunking is still invariant. Value-based learners hold bitwise
    (the per-tick optimization_barrier pins tick boundaries); policy-
    gradient learners' internal epoch scans compile k-dependently, so
    ppo is pinned to ~1-ulp agreement instead."""
    plan = DistPlan.flat(1, sync="ssp", staleness_bound=1, max_delay=1)
    (s2, h2), (s4, h4) = _chunk_pair("dqn", pipeline=True, plan=plan)
    _assert_bitwise(s2, s4)
    assert _hist_equal(h2, h4)
    (s2, h2), (s4, h4) = _chunk_pair("ppo", pipeline=True, plan=plan)
    for a, b in zip(jax.tree_util.tree_leaves(s2.params),
                    jax.tree_util.tree_leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-5)
    assert [r["iter"] for r in h2] == [r["iter"] for r in h4]


# ------------------------------------------------------- CLI contract
def test_cli_pipeline_flag_reports_depth_and_capacity():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train", "--algo", "dqn",
         "--plan", "workers=2:allreduce:ssp", "--staleness-bound", "1",
         "--pipeline", "--iters", "4", "--superstep", "2", "--n-envs",
         "8", "--unroll", "4", "--log-every", "2"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["pipeline"] is True
    assert out["pipeline_depth"] == 1
    assert out["pipeline_capacity"] == 1
    assert out["history"]


# ------------------- HostPipelined: the queue-free Fig. 5a baseline
def test_host_pipelined_stays_unregistered_and_queue_free():
    """HostPipelined is the survey's Fig. 5a CPU-simulation baseline:
    every env step round-trips through the host, so experience
    generation is CLOSED-LOOP — step t+1's input is step t's output via
    host memory, and no trajectory can be produced ahead of time. That
    is exactly the coupling the trajectory queue exists to break, so
    the wrapper deliberately stays out of the registry (no `envs.make`
    name) and owns no queue machinery of its own."""
    import repro.envs as envs
    assert not any("host" in name for name in envs.available())
    env = HostPipelined(CartPole())
    assert not hasattr(env, "queue") and not hasattr(env, "prefetch")


def test_host_pipelined_composes_with_pipelined_trainer():
    """Composability: the wrapper still runs under pipeline=True — the
    io_callback round-trip simply executes inside the producer program,
    serializing it (the measured Fig. 5a cost) without changing the
    numerics vs the on-device env."""
    plan = DistPlan.flat(1, sync="ssp", staleness_bound=1, max_delay=1)

    def run(env):
        cfg = TrainerConfig(algo="impala", iters=2, superstep=2,
                            n_envs=4, unroll=4, plan=plan, log_every=1,
                            seed=0, pipeline=True,
                            algo_kwargs={"hidden": (8,)})
        return Trainer(env, cfg).fit()

    _, h_host = run(HostPipelined(CartPole()))
    _, h_dev = run(CartPole())
    assert all(np.isfinite(r["loss"]) for r in h_host)
    assert _hist_equal(h_host, h_dev)
