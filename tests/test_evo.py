"""Evolutionary training (survey §7): ES gradient-estimator property,
GA seed-chain encoding determinism, learning sanity, comm accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-fallback

from repro.core.evo.es import centered_ranks


@given(seed=st.integers(0, 1000), n=st.integers(4, 64))
@settings(max_examples=15, deadline=None)
def test_centered_ranks_properties(seed, n):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    r = centered_ranks(x)
    assert float(jnp.abs(r.sum())) < 1e-4          # zero mean
    assert float(r.max()) == pytest.approx(0.5)
    assert float(r.min()) == pytest.approx(-0.5)
    # monotone: ranking preserves order
    order = jnp.argsort(x)
    assert bool(jnp.all(jnp.diff(r[order]) >= 0))


def test_es_gradient_estimator_unbiased_direction():
    """On a quadratic f(θ)=-|θ-θ*|², the (unshaped) ES gradient estimate
    must align with the analytic gradient (survey §7.1, Eq. 2)."""
    key = jax.random.PRNGKey(0)
    theta_star = jnp.array([1.0, -2.0, 0.5, 3.0])
    theta = jnp.zeros((4,))
    sigma = 0.1
    n = 4096
    eps = jax.random.normal(key, (n // 2, 4))
    eps = jnp.concatenate([eps, -eps])
    f = lambda t: -jnp.sum((t - theta_star) ** 2)
    fits = jax.vmap(f)(theta[None] + sigma * eps)
    grad_es = (fits[:, None] * eps).mean(0) / sigma
    grad_true = jax.grad(f)(theta)
    cos = jnp.dot(grad_es, grad_true) / (
        jnp.linalg.norm(grad_es) * jnp.linalg.norm(grad_true))
    assert float(cos) > 0.95, float(cos)


class _PointMass:
    """Smooth continuous-control env for deterministic ES testing —
    also exercises the duck-typed env contract: any object with a spec
    and pure reset/obs/step works with the rollout/fitness engine."""
    from repro.envs import EnvSpec, box
    spec = EnvSpec("point-mass", observation=box((2,)),
                   action=box((2,), low=-2.0, high=2.0), episode_len=30)
    discrete = False

    def reset(self, key):
        return {"p": jax.random.normal(key, (2,)),
                "t": jnp.zeros((), jnp.int32)}

    def obs(self, s):
        return s["p"]

    def step(self, s, a):
        p = s["p"] + 0.1 * jnp.clip(a.reshape(2), -2, 2)
        t = s["t"] + 1
        ns = {"p": p, "t": t}
        return ns, p, -jnp.sum(p ** 2), t >= 30


def test_es_improves_point_mass():
    from repro.core.networks import MLPPolicy
    from repro.core.evo import ES
    env = _PointMass()
    pol = MLPPolicy.for_spec(env.spec, hidden=(8,))
    es = ES(pol, env, pop_size=32, sigma=0.2, lr=0.1, max_steps=30)
    theta = es.init(jax.random.PRNGKey(1))
    step = jax.jit(es.step)
    fs = []
    for g in range(15):
        theta, f, comm = step(theta, jax.random.fold_in(
            jax.random.PRNGKey(2), g))
        fs.append(float(f))
    assert min(fs[-3:]) > fs[0], fs
    assert comm == 4 * 32  # one f32 fitness per member


def test_ga_seed_chain_reconstruction_deterministic():
    from repro.envs import CartPole
    from repro.core.networks import MLPPolicy
    from repro.core.evo import DeepGA
    env = CartPole()
    pol = MLPPolicy(env.obs_dim, env.n_actions, hidden=(8,))
    ga = DeepGA(pol, env, pop_size=4, chain_len=8)
    ga.init(jax.random.PRNGKey(0))
    chain = jnp.array([5, 17, 3, 0, 0, 0, 0, 0], jnp.uint32)
    t1 = ga.reconstruct(chain, jnp.int32(3))
    t2 = ga.reconstruct(chain, jnp.int32(3))
    np.testing.assert_array_equal(t1, t2)
    # longer chain differs
    t3 = ga.reconstruct(chain.at[3].set(99), jnp.int32(4))
    assert not bool(jnp.allclose(t1, t3))


def test_ga_improves_cartpole():
    from repro.envs import CartPole
    from repro.core.networks import MLPPolicy
    from repro.core.evo import DeepGA
    env = CartPole()
    pol = MLPPolicy(env.obs_dim, env.n_actions, hidden=(8,))
    ga = DeepGA(pol, env, pop_size=24, truncation=6, sigma=0.3,
                max_steps=100)
    state = ga.init(jax.random.PRNGKey(0))
    step = jax.jit(ga.step)
    best = []
    for g in range(8):
        state, bf, _ = step(state, jax.random.fold_in(
            jax.random.PRNGKey(1), g))
        best.append(float(bf))
    assert max(best[-3:]) >= best[0], best


def test_erl_injection_runs():
    from repro.envs import Pendulum
    from repro.core.networks import MLPPolicy
    from repro.core.evo import ERL
    from repro.optim import adamw
    env = Pendulum()
    pol = MLPPolicy.for_spec(env.spec, hidden=(8,))
    erl = ERL(pol, env, pop_size=4, max_steps=30, inject_every=1)
    state, replay = erl.init(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    ostate = opt.init(pol.init(jax.random.PRNGKey(1)))
    for g in range(2):
        state, ostate, fits = erl.step(
            state, replay, jax.random.fold_in(jax.random.PRNGKey(2), g),
            opt, ostate, learner_updates=2)
    assert bool(jnp.all(jnp.isfinite(fits)))
    assert bool(jnp.all(jnp.isfinite(state["pop"])))
