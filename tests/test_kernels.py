"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracle in each kernel's ref.py (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.advantages import ops as adv_ops
from repro.kernels.advantages.ref import (discounted_return_ref, gae_ref,
                                          nstep_return_ref)
from repro.kernels.flash_attention.kernel import flash_attention_hsd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gmm.ops import gmm
from repro.kernels.gmm.ref import gmm_ref
from repro.kernels.replay_sample.ops import prioritized_sample
from repro.kernels.replay_sample.ref import (prioritized_sample_ref,
                                             prioritized_weights_ref,
                                             shard_gumbel_topk_ref)
from repro.kernels.vtrace.ops import vtrace as vtrace_k
from repro.kernels.vtrace.ref import vtrace_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("B,H,KVH,S,D,causal,window", [
    (2, 4, 2, 256, 64, True, 0),
    (1, 4, 1, 256, 64, True, 64),      # sliding window, GQA kv=1
    (2, 2, 2, 128, 32, False, 0),      # non-causal (encoder)
    (1, 8, 4, 384, 128, True, 128),    # non-multiple S (padding path)
    (1, 2, 1, 512, 256, True, 0),      # gemma-style head_dim=256
])
def test_flash_attention_sweep(B, H, KVH, S, D, causal, window, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KVH, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KVH, S, D), jnp.float32)
    o = flash_attention_hsd(q, k, v, causal=causal, window=window)
    r = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(o, r, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_dtypes(dtype, rng):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64)).astype(dt)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dt)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dt)
    o = flash_attention_hsd(q, k, v, causal=True)
    r = attention_ref(q, k, v, causal=True)
    atol = 3e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=atol)


def test_flash_wrapper_layout(rng):
    """(B,S,KVH,G,D) wrapper layout matches the model-side jnp path."""
    from repro.models.attention import causal_attention
    B, S, KVH, G, D = 1, 200, 2, 2, 32
    ks = jax.random.split(rng, 3)
    qg = jax.random.normal(ks[0], (B, S, KVH, G, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    o1 = flash_attention(qg, k, v, causal=True, bq=128, bk=128)
    o2 = causal_attention(qg, k, v, jnp.int32(0), n_q_chunks=4,
                          block_k=64)
    np.testing.assert_allclose(o1, o2, atol=2e-5)


@pytest.mark.parametrize("B,S,KVH,G,D,causal,window", [
    (2, 128, 2, 2, 32, True, 0),
    (1, 256, 1, 4, 64, True, 64),      # sliding window, MQA kv=1
    (2, 96, 2, 1, 32, False, 0),       # non-causal, non-multiple S
])
def test_attention_dispatcher_parity(B, S, KVH, G, D, causal, window,
                                     rng):
    """core/attention.py dispatcher: ref path == Pallas kernel path in
    the trunk's (B, S, KVH, G, D) grouped-query layout."""
    from repro.core.attention import attention
    ks = jax.random.split(rng, 3)
    qg = jax.random.normal(ks[0], (B, S, KVH, G, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    o_ref = attention(qg, k, v, causal=causal, window=window,
                      use_kernel=False)
    o_ops = flash_attention(qg, k, v, causal=causal, window=window)
    assert o_ref.shape == (B, S, KVH, G, D)
    np.testing.assert_allclose(o_ref, o_ops, atol=2e-5, rtol=2e-5)


def test_attention_dispatcher_kernel_flag_off_tpu(rng):
    """use_kernel=True falls back to the ref path bitwise off-TPU
    (interpret-mode guard) — same convention as core/vtrace.py."""
    from repro.core.attention import attention
    from repro.kernels.common import interpret_mode
    assert interpret_mode()  # this suite never runs on TPU
    ks = jax.random.split(rng, 3)
    qg = jax.random.normal(ks[0], (1, 64, 2, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    a = attention(qg, k, v, causal=True, use_kernel=True)
    b = attention(qg, k, v, causal=True, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------- wkv6
@pytest.mark.parametrize("B,T,H,N,chunk", [
    (2, 100, 3, 16, 32),
    (1, 64, 2, 64, 64),
    (1, 37, 1, 8, 16),                 # padding path
])
def test_wkv6_sweep(B, T, H, N, chunk, rng):
    ks = jax.random.split(rng, 4)
    r = jax.random.normal(ks[0], (B, T, H, N))
    k = jax.random.normal(ks[1], (B, T, H, N))
    v = jax.random.normal(ks[2], (B, T, H, N))
    logw = -jnp.exp(0.5 * jax.random.normal(ks[3], (B, T, H, N)))
    u = 0.3 * jnp.ones((H, N))
    y_ref, _ = wkv6_ref(r, k, v, logw, u)
    y_k = wkv6(r, k, v, logw, u, chunk=chunk)
    np.testing.assert_allclose(y_k, y_ref, atol=2e-4, rtol=1e-3)


def test_wkv6_model_chunked_matches_ref(rng):
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, N = 2, 50, 2, 16
    ks = jax.random.split(rng, 4)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) for i in range(3))
    logw = -jnp.exp(0.5 * jax.random.normal(ks[3], (B, T, H, N)))
    u = 0.1 * jnp.ones((H, N))
    state0 = 0.2 * jax.random.normal(rng, (B, H, N, N))
    y_ref, s_ref = wkv6_ref(r, k, v, logw, u, state0)
    y_c, s_c = wkv_chunked(r, k, v, logw, u, state0, chunk=16)
    np.testing.assert_allclose(y_c, y_ref, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(s_c, s_ref, atol=2e-4, rtol=1e-3)


# ----------------------------------------------------------------- gmm
@pytest.mark.parametrize("E,C,d,f", [
    (4, 70, 96, 130),                  # padding on every axis
    (2, 128, 128, 128),                # exact tiles
    (8, 16, 512, 64),
])
def test_gmm_sweep(E, C, d, f, rng):
    x = jax.random.normal(rng, (E, C, d))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (E, d, f))
    np.testing.assert_allclose(gmm(x, w), gmm_ref(x, w),
                               atol=3e-4, rtol=1e-4)


def test_gmm_bf16(rng):
    x = jax.random.normal(rng, (2, 64, 64)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(rng, 1), (2, 64, 64))
    o = gmm(x, w)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(gmm_ref(x, w), np.float32),
                               atol=0.2, rtol=0.05)


# --------------------------------------------------------------- vtrace
@pytest.mark.parametrize("T,B", [(37, 9), (64, 128), (128, 1)])
def test_vtrace_kernel_sweep(T, B, rng):
    ks = jax.random.split(rng, 4)
    lr = 0.3 * jax.random.normal(ks[0], (T, B))
    disc = 0.99 * (jax.random.uniform(ks[1], (T, B)) > 0.05)
    rew = jax.random.normal(ks[2], (T, B))
    val = jax.random.normal(ks[3], (T, B))
    boot = jax.random.normal(ks[0], (B,))
    vs1, a1 = vtrace_ref(lr, disc, rew, val, boot)
    vs2, a2 = vtrace_k(lr, disc, rew, val, boot)
    np.testing.assert_allclose(vs1, vs2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(a1, a2, atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------- advantages
def _adv_inputs(T, B, rng):
    ks = jax.random.split(rng, 4)
    rew = jax.random.normal(ks[0], (T, B))
    val = jax.random.normal(ks[1], (T, B))
    dones = jax.random.uniform(ks[2], (T, B)) < 0.1
    boot = jax.random.normal(ks[3], (B,))
    return rew, val, dones, boot


@pytest.mark.parametrize("T,B", [(37, 9), (64, 128), (128, 1)])
def test_advantages_kernel_sweep(T, B, rng):
    """The single reverse-scan kernel reproduces BOTH estimators built
    on it (GAE and n-step returns) against the scan oracle, including
    the non-multiple-of-bb padding path."""
    rew, val, dones, boot = _adv_inputs(T, B, rng)
    a1, r1 = gae_ref(rew, val, dones, boot, 0.99, 0.95)
    a2, r2 = adv_ops.gae(rew, val, dones, boot, 0.99, 0.95)
    np.testing.assert_allclose(a1, a2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(r1, r2, atol=1e-5, rtol=1e-5)
    n1 = nstep_return_ref(rew, dones, boot, 0.99)
    n2 = adv_ops.nstep_return(rew, dones, boot, 0.99)
    np.testing.assert_allclose(n1, n2, atol=1e-5, rtol=1e-5)


def test_advantages_generic_recurrence(rng):
    T, B = 50, 40
    ks = jax.random.split(rng, 3)
    base = jax.random.normal(ks[0], (T, B))
    coef = jax.random.uniform(ks[1], (T, B))
    init = jax.random.normal(ks[2], (B,))
    np.testing.assert_allclose(
        discounted_return_ref(base, coef, init),
        adv_ops.discounted_return(base, coef, init),
        atol=1e-5, rtol=1e-5)


def test_advantages_ref_pins_legacy_inline_scans(rng):
    """The oracle is BITWISE the scans that used to live inline in
    algos/ppo.py (GAE) and algos/a3c.py (n-step) — guards the
    'numerically unchanged training' acceptance criterion."""
    gamma, lam = 0.99, 0.95
    rew, val, dones, boot = _adv_inputs(33, 7, rng)
    values_tp1 = jnp.concatenate([val[1:], boot[None]], axis=0)
    nonterm = 1.0 - dones.astype(jnp.float32)
    deltas = rew + gamma * nonterm * values_tp1 - val

    def show(acc, xs):
        delta, nt = xs
        acc = delta + gamma * lam * nt * acc
        return acc, acc

    _, adv_legacy = jax.lax.scan(show, jnp.zeros_like(boot),
                                 (deltas, nonterm), reverse=True)
    adv, ret = gae_ref(rew, val, dones, boot, gamma, lam)
    assert np.array_equal(np.asarray(adv), np.asarray(adv_legacy))
    assert np.array_equal(np.asarray(ret), np.asarray(adv_legacy + val))

    disc = gamma * (1.0 - dones.astype(jnp.float32))

    def nstep_body(acc, xs):
        r, d = xs
        acc = r + d * acc
        return acc, acc

    _, ret_legacy = jax.lax.scan(nstep_body, boot, (rew, disc),
                                 reverse=True)
    assert np.array_equal(
        np.asarray(nstep_return_ref(rew, dones, boot, gamma)),
        np.asarray(ret_legacy))


# --------------------------------------------------------- replay_sample
@pytest.mark.parametrize("C,size,n", [
    (512, 300, 64),
    (2048, 2048, 128),                 # full buffer
    (256, 17, 16),                     # nearly-empty, n == size-1 range
    (131, 100, 1),                     # odd capacity, single draw
    (64, 10, 32),                      # degenerate n > size fallback
])
def test_replay_sample_kernel_matches_ref(C, size, n, rng):
    ks = jax.random.split(rng, 2)
    prio = jnp.abs(jax.random.normal(ks[0], (C,))) + 0.01
    gumbel = jax.random.gumbel(ks[1], (C,))
    i1, w1 = prioritized_sample_ref(prio, size, gumbel, n)
    i2, w2 = prioritized_sample(prio, jnp.int32(size), gumbel, n)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(w1, w2, atol=1e-5, rtol=1e-5)
    assert bool((i1 < size).all()), "never returns an unfilled slot"


def test_replay_sample_without_replacement_and_valid(rng):
    C, size, n = 512, 400, 64
    ks = jax.random.split(rng, 2)
    prio = jnp.abs(jax.random.normal(ks[0], (C,))) + 0.01
    idx, w = prioritized_sample(
        prio, jnp.int32(size), jax.random.gumbel(ks[1], (C,)), n)
    idx = np.asarray(idx)
    assert len(set(idx.tolist())) == n, "Gumbel-top-k: no replacement"
    assert (idx < size).all(), "must never sample unfilled slots"
    w = np.asarray(w)
    assert ((w > 0) & (w <= 1.0 + 1e-6)).all() and w.max() == \
        pytest.approx(1.0)


# ------------------------------------- sharded replay merge (PR 9 seam)
@pytest.mark.parametrize("size", [64, 33, 16, 7, 5, 1, 0])
def test_shard_topk_merge_matches_flat_sample(size, rng):
    """Per-shard top-k (shard_gumbel_topk_ref) -> shard-major concat ->
    global top-n -> degenerate rule -> prioritized_weights_ref is
    BITWISE the flat prioritized_sample_ref at every fill level —
    top_k's stable tie-break (lower input position wins) survives the
    merge because shard-major concat preserves global index order. Ties
    are forced in both priorities and Gumbel noise to exercise it."""
    C, R, n = 64, 4, 16
    chunk = C // R
    ks = jax.random.split(rng, 2)
    prio = jnp.abs(jax.random.normal(ks[0], (C,))) + 0.01
    prio = prio.at[1::7].set(prio[0])          # cross-shard prio ties
    gumbel = jax.random.gumbel(ks[1], (C,))
    gumbel = gumbel.at[1::7].set(gumbel[0])    # -> exact score ties
    fi, fw = prioritized_sample_ref(prio, size, gumbel, n)

    nvalid = max(size, 1)  # GLOBAL guard only: slot 0 of shard 0
    k = min(n, chunk)
    cand_s, cand_i = [], []
    for r in range(R):
        lv = int(np.clip(nvalid - r * chunk, 0, chunk))  # NO local guard
        s, li = shard_gumbel_topk_ref(prio[r * chunk:(r + 1) * chunk], lv,
                                      gumbel[r * chunk:(r + 1) * chunk],
                                      k)
        cand_s.append(s)
        cand_i.append(li + r * chunk)
    _, pos = jax.lax.top_k(jnp.concatenate(cand_s), n)
    idx = jnp.concatenate(cand_i)[pos]
    idx = jnp.where(jnp.arange(n) < nvalid, idx, idx[0]).astype(jnp.int32)
    w = prioritized_weights_ref(prio, size, idx)
    assert np.array_equal(np.asarray(fi), np.asarray(idx))
    assert np.array_equal(np.asarray(fw), np.asarray(w))


def test_shard_topk_dispatcher_kernel_flag_off_tpu(rng):
    """core/replay_sample.py's shard_gumbel_topk dispatcher:
    use_kernel=True falls back to the ref bitwise off-TPU (interpret-
    mode guard), same convention as fused_prioritized_sample."""
    from repro.core.replay_sample import shard_gumbel_topk
    from repro.kernels.common import interpret_mode
    assert interpret_mode()  # this suite never runs on TPU
    ks = jax.random.split(rng, 2)
    prio = jnp.abs(jax.random.normal(ks[0], (128,))) + 0.01
    gumbel = jax.random.gumbel(ks[1], (128,))
    a = shard_gumbel_topk(prio, jnp.int32(70), gumbel, 16,
                          use_kernel=True)
    b = shard_gumbel_topk(prio, jnp.int32(70), gumbel, 16,
                          use_kernel=False)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ------------------------------- priority write-back round-trips (PR 9)
@pytest.mark.parametrize("fused", [False, True])
def test_replay_priority_writeback_round_trip(fused, rng):
    """sample -> TD errors -> update_priorities -> resample on both flat
    paths (legacy categorical, fused Gumbel-top-k): the write-back lands
    |td|+eps exactly on the sampled slots, leaves every other slot
    untouched, and the resample is deterministic and draws from the
    updated mass (a slot boosted to dominance must be drawn). TD values
    are a function of the index so categorical's with-replacement
    duplicates scatter identical values (deterministic on both paths)."""
    from repro.core.replay import PrioritizedReplay
    C, size, n = 128, 100, 32
    buf = PrioritizedReplay(C, fused=fused)
    ks = jax.random.split(rng, 3)
    state = buf.init({"obs": jnp.zeros((3,))})
    state = buf.add_batch(
        state, {"obs": jax.random.normal(ks[0], (size, 3))},
        jnp.abs(jax.random.normal(ks[1], (size,))) + 0.1)

    _, idx, _ = buf.sample(state, ks[2], n)
    td = (idx.astype(jnp.float32) + 1.0) * 0.1  # duplicate-safe
    state2 = buf.update_priorities(state, idx, td)
    prio = np.asarray(state2["prio"])
    np.testing.assert_allclose(prio[np.asarray(idx)],
                               np.abs(np.asarray(td)) + buf.eps,
                               rtol=1e-6)
    untouched = np.setdiff1d(np.arange(C), np.asarray(idx))
    np.testing.assert_array_equal(prio[untouched],
                                  np.asarray(state["prio"])[untouched])

    k2 = jax.random.fold_in(ks[2], 1)
    b1, i1, w1 = buf.sample(state2, k2, n)
    b2, i2, w2 = buf.sample(state2, k2, n)
    for a, b in zip(jax.tree_util.tree_leaves((b1, i1, w1)),
                    jax.tree_util.tree_leaves((b2, i2, w2))):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    boosted = int(np.asarray(idx)[0])
    state3 = buf.update_priorities(
        state2, jnp.asarray([boosted]), jnp.asarray([1e6]))
    _, i3, _ = buf.sample(state3, jax.random.fold_in(k2, 2), n)
    assert boosted in np.asarray(i3).tolist()


def test_replay_writeback_state_identical_across_paths(rng):
    """Given the SAME sampled indices and TD errors, the categorical and
    fused buffers and the sharded service write bitwise-identical
    priority state — update_priorities is path-independent, so a
    checkpoint taken after write-back is portable across sampling paths
    and plans."""
    from repro.core.replay import PrioritizedReplay
    from repro.core.replay_service import ShardedPrioritizedReplay
    C, size, n = 64, 50, 16
    ks = jax.random.split(rng, 3)
    batch = {"obs": jax.random.normal(ks[0], (size, 3))}
    prio0 = jnp.abs(jax.random.normal(ks[1], (size,))) + 0.1
    cat = PrioritizedReplay(C, fused=False)
    fus = PrioritizedReplay(C, fused=True)
    svc = ShardedPrioritizedReplay(C, "rp", 4)
    cstate = cat.add_batch(cat.init({"obs": jnp.zeros((3,))}), batch,
                           prio0)
    fstate = fus.add_batch(fus.init({"obs": jnp.zeros((3,))}), batch,
                           prio0)
    _, idx, _ = fus.sample(fstate, ks[2], n)
    td = jax.random.normal(jax.random.fold_in(ks[2], 1), (n,))
    c2 = cat.update_priorities(cstate, idx, td)
    f2 = fus.update_priorities(fstate, idx, td)
    s2 = jax.vmap(svc.update_priorities, in_axes=(0, None, None),
                  axis_name="rp")(svc.shard_state(fstate), idx, td)
    np.testing.assert_array_equal(np.asarray(c2["prio"]),
                                  np.asarray(f2["prio"]))
    np.testing.assert_array_equal(
        np.asarray(f2["prio"]),
        np.asarray(svc.unshard_state(s2)["prio"]))
