"""Sharded replay service tests (replay-role DistPlan axis).

Unit layer runs `ShardedPrioritizedReplay` under vmap named axes (the
same collectives shard_map lowers, no fake devices needed) and pins it
draw-for-draw/bitwise against the flat fused `PrioritizedReplay`; the
trainer layer spawns an 8-fake-device subprocess and pins the DQN fit
matrix: size-1 replay axis bitwise no-op, 2-shard replay plan bitwise
the flat plan, and the zero3+replay composition bitwise the flat plan
of the same data-device count."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distribution import DistPlan
from repro.core.replay import PrioritizedReplay
from repro.core.replay_service import ShardedPrioritizedReplay
from repro.core.trainer import Trainer, TrainerConfig
from repro.envs import CartPole

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _example():
    return {"obs": jnp.zeros((3,)), "action": jnp.zeros((), jnp.int32),
            "reward": jnp.zeros(()), "done": jnp.zeros((), bool)}


def _transitions(key, n):
    ks = jax.random.split(key, 3)
    return {"obs": jax.random.normal(ks[0], (n, 3)),
            "action": jax.random.randint(ks[1], (n,), 0, 4),
            "reward": jax.random.normal(ks[2], (n,)),
            "done": jax.random.uniform(ks[0], (n,)) < 0.2}


def _vm(svc, fn, n_rest):
    """Run a service method under the vmap stand-in for the mesh axis:
    sharded state has a leading (n_shards,) dim, the `n_rest` remaining
    args are broadcast."""
    return jax.vmap(fn, in_axes=(0,) + (None,) * n_rest,
                    axis_name=svc.axis)


def _bitwise(t1, t2):
    l1 = jax.tree_util.tree_leaves(t1)
    l2 = jax.tree_util.tree_leaves(t2)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.dtype == b.dtype, (a, b)
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------- unit (vmap collectives)
@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("fill", [0, 1, 7, 33, 64])
def test_service_sample_matches_flat_fused(n_shards, fill, rng):
    """Same key -> identical (batch, idx, weights) on every member AND
    bitwise the flat fused Gumbel-top-k draw, at every fill level incl.
    empty (slot-0 degenerate) and full."""
    C, n = 64, 16
    flat = PrioritizedReplay(C, fused=True)
    svc = ShardedPrioritizedReplay(C, "rp", n_shards)
    state = flat.init(_example())
    if fill:
        ks = jax.random.split(rng, 2)
        state = flat.add_batch(state, _transitions(ks[0], fill),
                               jnp.abs(jax.random.normal(ks[1],
                                                         (fill,))) + 0.1)
    fb, fi, fw = flat.sample(state, rng, n)
    sb, si, sw = _vm(svc, svc.sample, 2)(svc.shard_state(state), rng, n)
    for r in range(n_shards):  # every member returns the global result
        _bitwise((fb, fi, fw),
                 jax.tree_util.tree_map(lambda a, r=r: a[r], (sb, si, sw)))


@pytest.mark.parametrize("n", [24, 64])
@pytest.mark.parametrize("fill", [0, 7, 33, 64])
def test_service_sample_n_exceeding_chunk_matches_flat(n, fill, rng):
    """Draws larger than one shard's chunk (n > capacity//n_shards): the
    per-shard top-k clamps to k = min(n, chunk) candidates and the
    all-gather merge must still reproduce the flat fused draw
    draw-for-draw — including n == capacity, where every slot is a
    candidate. Guards the clamp + stable-merge tie ordering that the
    equal-size case never exercises."""
    C, n_shards = 64, 4  # chunk = 16 < n
    flat = PrioritizedReplay(C, fused=True)
    svc = ShardedPrioritizedReplay(C, "rp", n_shards)
    state = flat.init(_example())
    if fill:
        ks = jax.random.split(rng, 2)
        state = flat.add_batch(state, _transitions(ks[0], fill),
                               jnp.abs(jax.random.normal(ks[1],
                                                         (fill,))) + 0.1)
    fb, fi, fw = flat.sample(state, rng, n)
    sb, si, sw = _vm(svc, svc.sample, 2)(svc.shard_state(state), rng, n)
    for r in range(n_shards):
        _bitwise((fb, fi, fw),
                 jax.tree_util.tree_map(lambda a, r=r: a[r], (sb, si, sw)))


def test_service_add_batch_matches_flat(rng):
    """Insert path: identical ring plan, owner-routed scatter — the
    unsharded buffer is bitwise the flat buffer after partial fills,
    wrap-around and explicit-priority inserts."""
    C = 32
    flat = PrioritizedReplay(C, fused=True)
    svc = ShardedPrioritizedReplay(C, "rp", 4)
    fstate = flat.init(_example())
    sstate = svc.shard_state(fstate)
    add = _vm(svc, svc.add_batch, 2)
    for i, (n, with_prio) in enumerate([(5, False), (16, True),
                                        (20, False)]):  # wraps at 41 > 32
        k = jax.random.fold_in(rng, i)
        batch = _transitions(k, n)
        prio = (jnp.abs(jax.random.normal(k, (n,))) + 0.1
                if with_prio else None)
        fstate = flat.add_batch(fstate, batch, prio)
        sstate = (add(sstate, batch, prio) if with_prio
                  else _vm(svc, lambda s, b: svc.add_batch(s, b), 1)(
                      sstate, batch))
        _bitwise(fstate, svc.unshard_state(sstate))


def test_service_priority_writeback_round_trip(rng):
    """sample -> TD errors -> update_priorities -> resample: the
    write-back routes to the owning shard and the NEXT draw is bitwise
    the flat fused path's (the round-trip pin of satellite 3, service
    level)."""
    C, n = 64, 16
    flat = PrioritizedReplay(C, fused=True)
    svc = ShardedPrioritizedReplay(C, "rp", 4)
    ks = jax.random.split(rng, 4)
    fstate = flat.add_batch(flat.init(_example()),
                            _transitions(ks[0], 48))
    sstate = _vm(svc, lambda s, b: svc.add_batch(s, b), 1)(
        svc.shard_state(flat.init(_example())), _transitions(ks[0], 48))

    _, fi, _ = flat.sample(fstate, ks[1], n)
    _, si, _ = _vm(svc, svc.sample, 2)(sstate, ks[1], n)
    td = jax.random.normal(ks[2], (n,)) * 3.0
    fstate = flat.update_priorities(fstate, fi, td)
    sstate = _vm(svc, svc.update_priorities, 2)(sstate, si[0], td)
    _bitwise(fstate, svc.unshard_state(sstate))

    fb2, fi2, fw2 = flat.sample(fstate, ks[3], n)
    sb2, si2, sw2 = _vm(svc, svc.sample, 2)(sstate, ks[3], n)
    _bitwise((fb2, fi2, fw2),
             jax.tree_util.tree_map(lambda a: a[0], (sb2, si2, sw2)))
    # the write-back actually moved mass: updated slots carry |td|+eps
    np.testing.assert_allclose(
        np.asarray(fstate["prio"])[np.asarray(fi)],
        np.abs(np.asarray(td)) + flat.eps, rtol=1e-6)


def test_service_shard_unshard_round_trip(rng):
    svc = ShardedPrioritizedReplay(48, "rp", 4)
    flat = PrioritizedReplay(48, fused=True)
    state = flat.add_batch(flat.init(_example()), _transitions(rng, 30))
    _bitwise(state, svc.unshard_state(svc.shard_state(state)))
    sharded = svc.shard_state(state)
    assert sharded["prio"].shape == (4, 12)
    assert sharded["store"]["obs"].shape == (4, 12, 3)
    assert sharded["ptr"].shape == (4,)  # replicated scalars


def test_service_capacity_divisibility_error():
    with pytest.raises(ValueError, match="not divisible") as e:
        ShardedPrioritizedReplay(100, "rp", 3)
    assert "'rp'" in str(e.value) and "100" in str(e.value)


# --------------------------------------------- trainer validation errors
def test_trainer_replay_axis_rejects_unfused_dqn():
    """A replay axis over the legacy categorical sampler has no
    per-shard decomposition — the Trainer must refuse, naming the axis
    and the escape hatch."""
    with pytest.raises(ValueError, match="fused") as e:
        Trainer(CartPole(), TrainerConfig(
            algo="dqn", n_envs=8, plan=DistPlan.replay(1, 2),
            algo_kwargs={"fused_sampling": False}))
    assert "'replay'" in str(e.value)


def test_trainer_replay_axis_rejects_replayless_algo():
    """Algorithms without a prioritized buffer on the hot path can't
    ride a replay axis."""
    with pytest.raises(ValueError, match="replay") as e:
        Trainer(CartPole(), TrainerConfig(
            algo="ppo", n_envs=8, plan=DistPlan.replay(1, 2)))
    assert "'ppo'" in str(e.value)


def test_trainer_replay_axis_rejects_indivisible_capacity():
    with pytest.raises(ValueError, match="not divisible"):
        Trainer(CartPole(), TrainerConfig(
            algo="dqn", n_envs=8, plan=DistPlan.replay(1, 3),
            algo_kwargs={"replay_capacity": 1000}))


def test_trainer_replay_axis_rejects_pipeline():
    """pipeline=True reorders the add_batch/sample interleaving of the
    decoupled superstep against the sharded buffer — no validated
    parity, so the Trainer must refuse up front, naming the axis and
    the escape hatch (matching the zero3 x pipeline precedent)."""
    with pytest.raises(ValueError, match="pipeline") as e:
        Trainer(CartPole(), TrainerConfig(
            algo="dqn", n_envs=8, plan=DistPlan.replay(1, 2),
            pipeline=True))
    assert "'replay'" in str(e.value)
    assert "pipeline=False" in str(e.value)


# ------------- DQN fit parity matrix (8 fake devices, one subprocess):
# a replay group REPLICATES its data position's rollout/learner compute
# and shards only replay storage, so (workers=2, replay=R) must fit
# bitwise like flat(2) for every R, and composing zero3+replay like
# flat(4) (shard axes ARE data positions, replay axes are NOT).
_REPLAY_PARITY_SCRIPT = textwrap.dedent("""
    import json
    import math
    import jax, numpy as np
    import repro.envs as envs
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig

    env = envs.make("cartpole")
    KW = {"hidden": (8,), "replay_capacity": 512, "warmup": 1}

    def fit(plan):
        cfg = TrainerConfig(algo="dqn", iters=6, superstep=3, n_envs=8,
                            unroll=6, plan=plan, log_every=1, seed=0,
                            algo_kwargs=dict(KW))
        state, hist = Trainer(env, cfg).fit()
        return jax.device_get(state), hist

    def eq(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.shape != b.shape or a.dtype != b.dtype:
            return False
        return bool(np.array_equal(a, b, equal_nan=a.dtype.kind == "f"))

    def bitwise(t1, t2):
        l1 = jax.tree_util.tree_leaves(t1)
        l2 = jax.tree_util.tree_leaves(t2)
        return len(l1) == len(l2) and all(eq(a, b)
                                          for a, b in zip(l1, l2))

    def hist_eq(h1, h2):
        def veq(a, b):
            a, b = float(a), float(b)
            return a == b or (math.isnan(a) and math.isnan(b))
        return len(h1) == len(h2) and all(
            r1.keys() == r2.keys() and all(veq(r1[k], r2[k]) for k in r1)
            for r1, r2 in zip(h1, h2))

    def cmp(tag, out, a, b, ha, hb):
        out[tag + "_params"] = bitwise(a.params, b.params)
        out[tag + "_opt"] = bitwise(a.opt_state, b.opt_state)
        out[tag + "_replay"] = bitwise(a.extra, b.extra)
        out[tag + "_ring"] = bitwise(a.ring, b.ring)
        out[tag + "_hist"] = hist_eq(ha, hb)

    out = {}
    s2, h2 = fit(DistPlan.flat(2))
    s21, h21 = fit(DistPlan.parse(
        "workers=2:allreduce:bsp,replay=1:allreduce:bsp:replay"))
    s22, h22 = fit(DistPlan.replay(2, 2))
    s2o, h2o = fit(DistPlan.parse(  # replay axis OUTERMOST
        "replay=2:allreduce:bsp:replay,workers=2:allreduce:bsp"))
    cmp("size1", out, s2, s21, h2, h21)
    cmp("size2", out, s2, s22, h2, h22)
    cmp("outer", out, s2, s2o, h2, h2o)

    s4, h4 = fit(DistPlan.flat(4))
    sz, hz = fit(DistPlan.parse(
        "workers=2:allreduce:bsp,shard=2:allreduce:bsp:zero3,"
        "replay=2:allreduce:bsp:replay"))
    cmp("zero3", out, s4, sz, h4, hz)
    print("RESULT " + json.dumps(out))
""")

_KEYS = ("params", "opt", "replay", "ring", "hist")


@pytest.fixture(scope="module")
def replay_parity_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _REPLAY_PARITY_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("part", _KEYS)
def test_replay_axis_size1_is_bitwise_noop(replay_parity_results, part):
    """Acceptance: appending a size-1 replay axis to the flat 2-worker
    plan is a bitwise no-op — params, opt_state, the full replay buffer,
    actor ring and metric history all match exactly (the axis is left
    unwrapped, a data axis by construction)."""
    assert replay_parity_results[f"size1_{part}"], replay_parity_results


@pytest.mark.slow
@pytest.mark.parametrize("part", _KEYS)
def test_replay_axis_size2_matches_flat_bitwise(replay_parity_results,
                                                part):
    """Acceptance: a (workers=2, replay=2) plan — per-shard Gumbel
    top-k, all-gather merge, psum batch assembly, owner-routed
    write-back — fits DQN bitwise like the flat 2-worker plan, with the
    reassembled replay buffer identical; same with the replay axis
    outermost (placement-independent)."""
    assert replay_parity_results[f"size2_{part}"], replay_parity_results
    assert replay_parity_results[f"outer_{part}"], replay_parity_results


@pytest.mark.slow
@pytest.mark.parametrize("part", _KEYS)
def test_replay_axis_composes_with_zero3(replay_parity_results, part):
    """Acceptance: (workers=2, shard=2:zero3, replay=2) — learner-state
    sharding and replay sharding on orthogonal axes — fits bitwise like
    flat(4): shard axes ARE data positions, replay axes are NOT."""
    assert replay_parity_results[f"zero3_{part}"], replay_parity_results
