"""Sharding rules + scaled-down dry-run integration (subprocess owns its
own device count; the main test process keeps 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh_stub(shape=(4, 4)):
    """AbstractMesh stand-in exposing .shape mapping for rule tests."""
    class M:
        def __init__(self):
            self.axis_names = (("pod", "data", "model")[-len(shape):])
            self.shape = dict(zip(self.axis_names, shape))
    return M()


def test_param_pspec_rules_divisible():
    from repro.launch.sharding import param_pspec
    m = _mesh_stub((16, 16))
    # attention heads divisible -> heads sharded
    spec = param_pspec("stack/t0/mixer/wq", (2048, 32, 64), m)
    assert spec == jax.sharding.PartitionSpec(None, "model", None)
    # heads NOT divisible (smollm 15H) -> fall through to head_dim
    spec = param_pspec("stack/t0/mixer/wq", (960, 15, 64), m)
    assert spec == jax.sharding.PartitionSpec(None, None, "model")
    # moe experts
    spec = param_pspec("stack/t0/ffn/wi", (64, 2048, 1408), m)
    assert spec == jax.sharding.PartitionSpec("model", None, None)
    # fsdp adds data on the largest free dim
    spec = param_pspec("stack/t0/ffn/wi", (64, 2048, 1408), m, fsdp=True)
    assert "data" in spec


def test_every_arch_params_get_valid_specs():
    """Every leaf's spec dims must divide by the axis size (the guarantee
    the rules promise)."""
    from repro.configs import list_archs
    from repro.launch.sharding import param_pspec, _path_str
    from repro.models import build_model
    from repro.models.model import ModelOpts
    m = _mesh_stub((16, 16))
    for arch in list_archs():
        model = build_model(arch, ModelOpts())
        struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        for path, leaf in jax.tree_util.tree_flatten_with_path(struct)[0]:
            if leaf.ndim == 0 or leaf.size < 1024:
                continue
            spec = param_pspec(_path_str(path), leaf.shape, m, fsdp=True)
            for i, s in enumerate(spec):
                if s is None:
                    continue
                n = 16
                assert leaf.shape[i] % n == 0, (arch, _path_str(path),
                                                leaf.shape, spec)


_DRYRUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    from repro.launch.dryrun import dryrun_one
    out = []
    for arch, shape in [("smollm-360m", "train_4k"),
                        ("gemma3-1b", "decode_32k"),
                        ("rwkv6-1.6b", "long_500k"),
                        ("whisper-base", "prefill_32k")]:
        r = dryrun_one(arch, shape, mesh_shape=(4, 4), save=False)
        out.append({k: r.get(k) for k in
                    ("arch", "shape", "status", "bottleneck", "error")})
    # multi-pod smoke (2,2,2 = 8 devices)
    r = dryrun_one("smollm-360m", "train_4k", multi_pod=True,
                   mesh_shape=(2, 2, 2), save=False)
    out.append({"arch": "smollm-360m", "shape": "train_4k+multipod",
                "status": r["status"], "error": r.get("error")})
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_scaled_mesh_compiles():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _DRYRUN_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    results = json.loads(line[len("RESULT "):])
    for res in results:
        assert res["status"] == "ok", res


def test_main_process_sees_one_device():
    """Guard: nothing in the test suite may set the 512-device flag
    globally (the spec's requirement)."""
    assert len(jax.devices()) == 1


def test_pure_dp_policy():
    from repro.launch.sharding import param_pspec, batch_sharding
    import jax.numpy as jnp
    m = _mesh_stub((16, 16))
    spec = param_pspec("stack/t0/mixer/wq", (2048, 32, 64), m,
                       policy="pure_dp")
    assert spec == jax.sharding.PartitionSpec(None, None, None)
    spec = param_pspec("stack/t0/ffn/wi", (2048, 8192), m, fsdp=True,
                       policy="pure_dp")
    assert "data" in spec and "model" not in spec


def test_wire_bytes_factors():
    from repro.launch.hlo_analysis import wire_bytes
    assert wire_bytes({"all-reduce": 100}) == 187.5
    assert wire_bytes({"all-gather": 160}) == 150.0
    assert wire_bytes({"collective-permute": 7}) == 7.0
