"""Unified Agent/Trainer API: registry round-trip, fused-vs-unfused
equivalence, the (topology x sync) smoke matrix on a fake 4-device mesh,
CLI contract, and the learning-sanity claims migrated off the legacy
per-algorithm drivers."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agent as agent_api
from repro.core.trainer import Trainer, TrainerConfig
from repro.envs import CartPole, GridWorld

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ALGOS = ("a3c", "dqn", "impala", "ppo")


# ------------------------------------------------------------- registry
def test_registry_lists_all_algorithms():
    assert set(ALGOS) <= set(agent_api.available())


@pytest.mark.parametrize("name", ALGOS)
def test_registry_roundtrip(name):
    """Every algorithm constructs by name, inits a TrainState pytree,
    and serves behavior params for any (clipped) delay."""
    env = CartPole()
    ag = agent_api.make(name, env=env, ring_size=3)
    state = ag.init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert isinstance(jax.tree_util.tree_unflatten(treedef, leaves),
                      agent_api.TrainState)
    fresh = ag.actor_policy(state, 0)
    stale = ag.actor_policy(state, 99)  # clipped to ring depth
    for a, b in zip(jax.tree_util.tree_leaves(fresh),
                    jax.tree_util.tree_leaves(stale)):
        assert a.shape == b.shape
        np.testing.assert_allclose(a, b)  # init: whole ring identical


def test_unknown_algo_raises():
    with pytest.raises(KeyError, match="unknown algorithm"):
        agent_api.make("nope", env=CartPole())


def test_ring_rotation_tracks_policy_lag():
    """After one learner step, delay-0 params are the new ones and
    delay-1 params are the previous ones."""
    env = CartPole()
    ag = agent_api.make("impala", env=env, ring_size=2,
                        hidden=(8,))
    state = ag.init(jax.random.PRNGKey(0))
    old = state.params
    key = jax.random.PRNGKey(1)
    env_state = env.reset_batch(key, 4)
    from repro.core.rollout import rollout
    traj, env_state = rollout(ag.policy, ag.actor_policy(state, 0), env,
                              key, env_state, 4)
    boot = jax.vmap(env.obs)(env_state)
    state, metrics = ag.learner_step(state, traj, boot, key)
    assert jnp.isfinite(metrics["loss"])
    lagged = ag.actor_policy(state, 1)
    for a, b in zip(jax.tree_util.tree_leaves(lagged),
                    jax.tree_util.tree_leaves(old)):
        np.testing.assert_allclose(a, b)
    newest = ag.actor_policy(state, 0)
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(newest),
        jax.tree_util.tree_leaves(old)))
    assert diff > 0


# --------------------------------------------------- episode accounting
def test_episode_accounting_exact_and_carried():
    """The episode_return metric is the mean return of episodes that
    COMPLETED this iteration; the per-env accumulator carries across
    iteration boundaries and zero-completion iterations report the last
    known value (NaN before any episode ever finished)."""
    run0 = jnp.zeros((2,))
    nan = jnp.full((), jnp.nan)
    rew = jnp.ones((3, 2))
    none_done = jnp.zeros((3, 2), bool)
    # iteration 1: nothing finishes -> NaN, accumulators keep counting
    run, ret = Trainer._episode_stats(run0, nan, {"reward": rew,
                                                  "done": none_done})
    assert np.isnan(float(ret))
    np.testing.assert_allclose(run, [3.0, 3.0])
    # iteration 2: env0 finishes at t=1 (episode return 3+1+1=5) and
    # restarts; env1 keeps running
    done = jnp.array([[False, False], [True, False], [False, False]])
    run, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                                 "done": done})
    assert float(ret) == pytest.approx(5.0)
    np.testing.assert_allclose(run, [1.0, 6.0])
    # iteration 3: nothing finishes -> last value carried, not a raw
    # sum; the accumulators keep growing ([1,6] + 3 steps of reward)
    run, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                                 "done": none_done})
    assert float(ret) == pytest.approx(5.0)
    np.testing.assert_allclose(run, [4.0, 9.0])
    # two completions in one block -> mean of both episode returns
    done2 = jnp.array([[True, True], [False, False], [False, False]])
    _, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                               "done": done2})
    assert float(ret) == pytest.approx(((4 + 1) + (9 + 1)) / 2)


# ------------------------------------------- fused superstep equivalence
def test_fused_superstep_equals_unfused():
    """Acceptance: K fused iterations in one scan produce the same
    params and metrics as per-iteration dispatch for a fixed seed."""
    env = CartPole()

    def run(fused):
        cfg = TrainerConfig(algo="impala", iters=8, superstep=4,
                            n_envs=8, unroll=8, log_every=4, seed=1,
                            algo_kwargs={"hidden": (16,)})
        return Trainer(env, cfg).fit(fused=fused)

    s_fused, h_fused = run(True)
    s_unfused, h_unfused = run(False)
    for a, b in zip(jax.tree_util.tree_leaves(s_fused.params),
                    jax.tree_util.tree_leaves(s_unfused.params)):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)
    assert [r["iter"] for r in h_fused] == [r["iter"] for r in h_unfused]
    for rf, ru in zip(h_fused, h_unfused):
        assert rf["loss"] == pytest.approx(ru["loss"], rel=1e-3)


# ------------------------------------- topology x sync smoke (4 devices)
_MATRIX_SCRIPT = textwrap.dedent("""
    import itertools, json, math
    import repro.envs as envs
    from repro.core.trainer import Trainer, TrainerConfig
    env = envs.make("cartpole")
    out = {}
    for topo, sync in itertools.product(("allreduce", "ps", "gossip"),
                                        ("bsp", "asp", "ssp")):
        cfg = TrainerConfig(algo="impala", iters=6, superstep=3,
                            n_envs=8, unroll=8, n_workers=4,
                            topology=topo, sync=sync, max_delay=2,
                            log_every=2, algo_kwargs={"hidden": (8,)})
        _, hist = Trainer(env, cfg).fit()
        last = hist[-1]
        # episode_return is NaN until the first episode completes (the
        # honest boundary accounting) — require losses always finite
        # and the final return real
        out[f"{topo}/{sync}"] = {
            "loss": last["loss"], "ret": last["episode_return"],
            "finite": (all(math.isfinite(r["loss"]) for r in hist)
                       and math.isfinite(last["episode_return"]))}
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def matrix_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _MATRIX_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_matrix_covers_all_combinations(matrix_results):
    assert len(matrix_results) == 9


def test_matrix_all_finite_and_nondegenerate(matrix_results):
    for combo, res in matrix_results.items():
        assert res["finite"], combo
        assert res["ret"] > 0, (combo, res)  # CartPole returns positive


def test_matrix_sync_topologies_agree(matrix_results):
    """ps and allreduce are mathematically identical aggregations — the
    same training run must come out (numerically) the same."""
    for sync in ("bsp", "asp", "ssp"):
        a = matrix_results[f"allreduce/{sync}"]["loss"]
        p = matrix_results[f"ps/{sync}"]["loss"]
        assert a == pytest.approx(p, rel=1e-3), (sync, a, p)


# ----------------------------------------------------------- validation
def test_bad_topology_and_sync_raise():
    env = CartPole()
    with pytest.raises(ValueError, match="topology"):
        Trainer(env, TrainerConfig(topology="star"))
    with pytest.raises(ValueError, match="sync"):
        Trainer(env, TrainerConfig(sync="eventual"))
    with pytest.raises(ValueError, match="divide"):
        Trainer(env, TrainerConfig(n_envs=6, n_workers=4))


# -------------------------------------------------------- CLI contract
def test_cli_a3c_with_topology_and_sync_flags():
    """Satellites: --topology/--sync/--n-workers exist and A3C is
    reachable from the CLI via the registry."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train", "--algo", "a3c",
         "--env", "cartpole", "--topology", "allreduce", "--sync", "asp",
         "--iters", "4", "--superstep", "2", "--n-envs", "8",
         "--unroll", "4", "--log-every", "2"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["algo"] == "a3c" and out["sync"] == "asp"
    assert out["history"]


def test_cli_rejects_unknown_topology():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--topology", "star"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=120)
    assert r.returncode != 0
    assert "--topology" in r.stderr


# ------------------------------------------- learning sanity (migrated)
def test_impala_policy_lag_vtrace_beats_naive():
    """Survey §6.1: under policy lag, V-trace correction must not be
    worse than the uncorrected learner (measured by final return)."""
    env = CartPole()
    rets = {}
    for use_vtrace in (True, False):
        cfg = TrainerConfig(algo="impala", iters=40, superstep=10,
                            n_envs=16, unroll=16, policy_lag=4, seed=3,
                            log_every=40,
                            algo_kwargs={"hidden": (32,),
                                         "use_vtrace": use_vtrace})
        _, hist = Trainer(env, cfg).fit()
        rets[use_vtrace] = hist[-1]["episode_return"]
    assert rets[True] >= 0.6 * rets[False], rets


def test_dqn_improves_on_gridworld():
    env = GridWorld(n=4, max_steps=16)
    cfg = TrainerConfig(algo="dqn", iters=60, superstep=10, n_envs=16,
                        unroll=8, log_every=20,
                        algo_kwargs={"warmup": 5, "eps_decay_steps": 40,
                                     "target_update": 20})
    _, hist = Trainer(env, cfg).fit()
    assert hist[-1]["episode_return"] > hist[0]["episode_return"], hist
