"""Unified Agent/Trainer API under the Distribution Plan API: registry
round-trip, fused-vs-unfused equivalence, the (collective x sync) smoke
matrix as 1-D plans on a fake 4-device mesh, the hierarchical 2-D plan
matrix on 8 fake devices (incl. flat-vs-nested bitwise parity), elastic
actor shards, CLI contract, and the learning-sanity claims."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agent as agent_api
from repro.core.distribution import AxisSpec, DistPlan
from repro.core.trainer import Trainer, TrainerConfig
from repro.envs import CartPole, GridWorld

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ALGOS = ("a3c", "dqn", "impala", "ppo")


# ------------------------------------------------------------- registry
def test_registry_lists_all_algorithms():
    assert set(ALGOS) <= set(agent_api.available())


@pytest.mark.parametrize("name", ALGOS)
def test_registry_roundtrip(name):
    """Every algorithm constructs by name, inits a TrainState pytree,
    and serves behavior params for any (clipped) delay."""
    env = CartPole()
    ag = agent_api.make(name, env=env, ring_size=3)
    state = ag.init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert isinstance(jax.tree_util.tree_unflatten(treedef, leaves),
                      agent_api.TrainState)
    fresh = ag.actor_policy(state, 0)
    stale = ag.actor_policy(state, 99)  # clipped to ring depth
    for a, b in zip(jax.tree_util.tree_leaves(fresh),
                    jax.tree_util.tree_leaves(stale)):
        assert a.shape == b.shape
        np.testing.assert_allclose(a, b)  # init: whole ring identical


def test_unknown_algo_raises():
    with pytest.raises(KeyError, match="unknown algorithm"):
        agent_api.make("nope", env=CartPole())


def test_ring_rotation_tracks_policy_lag():
    """After one learner step, delay-0 params are the new ones and
    delay-1 params are the previous ones."""
    env = CartPole()
    ag = agent_api.make("impala", env=env, ring_size=2,
                        hidden=(8,))
    state = ag.init(jax.random.PRNGKey(0))
    old = state.params
    key = jax.random.PRNGKey(1)
    env_state = env.reset_batch(key, 4)
    from repro.core.rollout import rollout
    traj, env_state = rollout(ag.policy, ag.actor_policy(state, 0), env,
                              key, env_state, 4)
    boot = jax.vmap(env.obs)(env_state)
    state, metrics = ag.learner_step(state, traj, boot, key)
    assert jnp.isfinite(metrics["loss"])
    lagged = ag.actor_policy(state, 1)
    for a, b in zip(jax.tree_util.tree_leaves(lagged),
                    jax.tree_util.tree_leaves(old)):
        np.testing.assert_allclose(a, b)
    newest = ag.actor_policy(state, 0)
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(newest),
        jax.tree_util.tree_leaves(old)))
    assert diff > 0


# --------------------------------------------------- episode accounting
def test_episode_accounting_exact_and_carried():
    """The episode_return metric is the mean return of episodes that
    COMPLETED this iteration; the per-env accumulator carries across
    iteration boundaries and zero-completion iterations report the last
    known value (NaN before any episode ever finished)."""
    run0 = jnp.zeros((2,))
    nan = jnp.full((), jnp.nan)
    rew = jnp.ones((3, 2))
    none_done = jnp.zeros((3, 2), bool)
    # iteration 1: nothing finishes -> NaN, accumulators keep counting
    run, ret = Trainer._episode_stats(run0, nan, {"reward": rew,
                                                  "done": none_done})
    assert np.isnan(float(ret))
    np.testing.assert_allclose(run, [3.0, 3.0])
    # iteration 2: env0 finishes at t=1 (episode return 3+1+1=5) and
    # restarts; env1 keeps running
    done = jnp.array([[False, False], [True, False], [False, False]])
    run, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                                 "done": done})
    assert float(ret) == pytest.approx(5.0)
    np.testing.assert_allclose(run, [1.0, 6.0])
    # iteration 3: nothing finishes -> last value carried, not a raw
    # sum; the accumulators keep growing ([1,6] + 3 steps of reward)
    run, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                                 "done": none_done})
    assert float(ret) == pytest.approx(5.0)
    np.testing.assert_allclose(run, [4.0, 9.0])
    # two completions in one block -> mean of both episode returns
    done2 = jnp.array([[True, True], [False, False], [False, False]])
    _, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                               "done": done2})
    assert float(ret) == pytest.approx(((4 + 1) + (9 + 1)) / 2)


# ----------------------------------------------------- DistPlan schema
def test_plan_defaults_to_flat_single_worker():
    plan = DistPlan.flat()
    assert plan.axis_names == ("workers",)
    assert plan.mesh_shape == (1,)
    assert plan.n_devices == 1 and plan.ring_extra == 0


def test_plan_parse_round_trip():
    s = "hosts=2:allreduce:bsp,workers=4:gossip:asp"
    plan = DistPlan.parse(s, max_delay=3)
    assert plan.axis_names == ("hosts", "workers")
    assert plan.mesh_shape == (2, 4)
    assert plan.axes[1].collective == "gossip"
    assert plan.axes[1].sync == "asp"
    assert plan.describe() == s
    assert plan.ring_extra == 3  # bsp(0) + asp(max_delay=3)


def test_plan_ring_extra_adds_across_axes():
    plan = DistPlan(axes=(
        AxisSpec("hosts", 2, sync="asp", max_delay=5),
        AxisSpec("workers", 2, sync="ssp", max_delay=5,
                 staleness_bound=2)))
    assert plan.ring_extra == 5 + 2
    cfg = TrainerConfig(plan=plan, policy_lag=1)
    assert cfg.ring_size == 1 + 7 + 1


def test_plan_delay_schedule_adds_per_axis():
    plan = DistPlan(axes=(
        AxisSpec("hosts", 2, sync="asp", max_delay=3),
        AxisSpec("workers", 4, sync="bsp")))
    d = plan.make_delay_schedule(10, jax.random.PRNGKey(0))
    assert d.shape == (10, 2, 4)
    # bsp inner axis adds nothing: delays constant across workers
    np.testing.assert_array_equal(
        np.asarray(d),
        np.broadcast_to(np.asarray(d)[:, :, :1], d.shape))
    assert int(d.max()) <= 3


def test_plan_flat_delay_schedule_matches_legacy_sync():
    """The 1-D plan consumes the key exactly as sync.make_delays did —
    the legacy schedule is bitwise what the plan produces."""
    from repro.core.sync import SyncConfig, make_delays
    key = jax.random.PRNGKey(3)
    plan = DistPlan.flat(4, sync="ssp", max_delay=6, staleness_bound=2)
    legacy = make_delays(SyncConfig("ssp", 4, 6, 2), 20, key)
    np.testing.assert_array_equal(
        np.asarray(plan.make_delay_schedule(20, key)), np.asarray(legacy))


def test_plan_validation_errors():
    with pytest.raises(ValueError, match="collective"):
        AxisSpec("workers", 2, collective="star")
    with pytest.raises(ValueError, match="sync"):
        AxisSpec("workers", 2, sync="eventual")
    with pytest.raises(ValueError, match="duplicate"):
        DistPlan(axes=(AxisSpec("w", 2), AxisSpec("w", 2)))
    with pytest.raises(ValueError, match="actors"):
        DistPlan.flat(1, actors=(4, 0))
    with pytest.raises(ValueError, match="divide"):
        Trainer(CartPole(), TrainerConfig(n_envs=6,
                                          plan=DistPlan.flat(4)))
    with pytest.raises(ValueError, match="actors"):
        Trainer(CartPole(), TrainerConfig(
            n_envs=8, plan=DistPlan.flat(4, actors=(8, 6))))


def test_plan_device_validation_names_count_and_shape():
    """Requesting a plan shape larger than the visible device count must
    raise a clear error naming both — never silently slice devices."""
    with pytest.raises(RuntimeError) as e:
        Trainer(CartPole(), TrainerConfig(n_envs=64,
                                          plan=DistPlan.flat(64)))
    msg = str(e.value)
    assert "64 devices" in msg and "workers=64" in msg
    assert "xla_force_host_platform_device_count" in msg


# ------------------------------------------- fused superstep equivalence
def test_fused_superstep_equals_unfused():
    """Acceptance: K fused iterations in one scan produce the same
    params and metrics as per-iteration dispatch for a fixed seed."""
    env = CartPole()

    def run(fused):
        cfg = TrainerConfig(algo="impala", iters=8, superstep=4,
                            n_envs=8, unroll=8, log_every=4, seed=1,
                            algo_kwargs={"hidden": (16,)})
        return Trainer(env, cfg).fit(fused=fused)

    s_fused, h_fused = run(True)
    s_unfused, h_unfused = run(False)
    for a, b in zip(jax.tree_util.tree_leaves(s_fused.params),
                    jax.tree_util.tree_leaves(s_unfused.params)):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)
    assert [r["iter"] for r in h_fused] == [r["iter"] for r in h_unfused]
    for rf, ru in zip(h_fused, h_unfused):
        assert rf["loss"] == pytest.approx(ru["loss"], rel=1e-3)


# -------------------------------------------------- elastic actor shards
def _hist_equal(h1, h2):
    """Bitwise history comparison; NaN (pre-first-episode) == NaN."""
    if len(h1) != len(h2):
        return False
    for r1, r2 in zip(h1, h2):
        if r1.keys() != r2.keys():
            return False
        for k in r1:
            if not np.array_equal(np.float64(r1[k]), np.float64(r2[k]),
                                  equal_nan=True):
                return False
    return True


def test_plan_elastic_actors_vary_shards_deterministically():
    """plan.actors cycles the env-shard count per superstep window; the
    per-shape numerics are pinned: two identical runs agree bitwise,
    the shard trace is exactly the schedule, and the unfused fit
    reshards at the same iteration boundaries (same numerics, one
    schedule entry per cfg.superstep iterations)."""
    env = CartPole()

    def run(fused=True):
        cfg = TrainerConfig(algo="impala", iters=9, superstep=3,
                            n_envs=8, unroll=6, log_every=1, seed=2,
                            plan=DistPlan.flat(1, actors=(8, 4, 8)),
                            algo_kwargs={"hidden": (8,)})
        tr = Trainer(env, cfg)
        state, hist = tr.fit(fused=fused)
        return state, hist, tr.actor_shards

    s1, h1, shards1 = run()
    s2, h2, shards2 = run()
    assert shards1 == [8, 4, 8] and shards2 == shards1
    assert _hist_equal(h1, h2)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s3, h3, shards3 = run(fused=False)
    assert shards3 == [8] * 3 + [4] * 3 + [8] * 3  # per-dispatch trace
    assert _hist_equal(h3, h1)
    for a, b in zip(jax.tree_util.tree_leaves(s3.params),
                    jax.tree_util.tree_leaves(s1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_constant_actors_schedule_is_bitwise_noop():
    """A constant actors= schedule equal to n_envs never reshards and
    is bitwise the plain run — elasticity is invisible to the agent."""
    env = CartPole()

    def run(plan):
        cfg = TrainerConfig(algo="impala", iters=6, superstep=3,
                            n_envs=8, unroll=6, log_every=1, seed=0,
                            plan=plan, algo_kwargs={"hidden": (8,)})
        tr = Trainer(env, cfg)
        state, hist = tr.fit()
        return state, hist

    s_c, h_c = run(DistPlan.flat(1, actors=(8,)))
    s_p, h_p = run(None)
    assert _hist_equal(h_c, h_p)
    for a, b in zip(jax.tree_util.tree_leaves(s_c.params),
                    jax.tree_util.tree_leaves(s_p.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------- collective x sync smoke (1-D plans, 4 devs)
_MATRIX_SCRIPT = textwrap.dedent("""
    import itertools, json, math
    import repro.envs as envs
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig
    env = envs.make("cartpole")
    out = {}
    for coll, sync in itertools.product(("allreduce", "ps", "gossip"),
                                        ("bsp", "asp", "ssp")):
        plan = DistPlan.flat(4, collective=coll, sync=sync, max_delay=2)
        cfg = TrainerConfig(algo="impala", iters=6, superstep=3,
                            n_envs=8, unroll=8, plan=plan,
                            log_every=2, algo_kwargs={"hidden": (8,)})
        _, hist = Trainer(env, cfg).fit()
        last = hist[-1]
        # episode_return is NaN until the first episode completes (the
        # honest boundary accounting) — require losses always finite
        # and the final return real
        out[f"{coll}/{sync}"] = {
            "loss": last["loss"], "ret": last["episode_return"],
            "finite": (all(math.isfinite(r["loss"]) for r in hist)
                       and math.isfinite(last["episode_return"]))}
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def matrix_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _MATRIX_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_matrix_covers_all_combinations(matrix_results):
    assert len(matrix_results) == 9


def test_matrix_all_finite_and_nondegenerate(matrix_results):
    for combo, res in matrix_results.items():
        assert res["finite"], combo
        assert res["ret"] > 0, (combo, res)  # CartPole returns positive


def test_matrix_sync_topologies_agree(matrix_results):
    """ps and allreduce are mathematically identical aggregations — the
    same training run must come out (numerically) the same."""
    for sync in ("bsp", "asp", "ssp"):
        a = matrix_results[f"allreduce/{sync}"]["loss"]
        p = matrix_results[f"ps/{sync}"]["loss"]
        assert a == pytest.approx(p, rel=1e-3), (sync, a, p)


# ----------------------- hierarchical 2-D plan matrix (8 fake devices)
_PLAN_MATRIX_SCRIPT = textwrap.dedent("""
    import itertools, json, math
    import jax, numpy as np
    import repro.envs as envs
    from repro.core.distribution import AxisSpec, DistPlan
    from repro.core.trainer import Trainer, TrainerConfig
    env = envs.make("cartpole")

    def fit(plan):
        cfg = TrainerConfig(algo="impala", iters=6, superstep=3,
                            n_envs=8, unroll=8, plan=plan,
                            log_every=1, seed=0,
                            algo_kwargs={"hidden": (8,)})
        return Trainer(env, cfg).fit()

    def bitwise(s1, s2):
        return all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                                   jax.tree_util.tree_leaves(s2.params)))

    def hist_eq(h1, h2):   # NaN-aware (pre-first-episode returns)
        return all(r1.keys() == r2.keys()
                   and all(np.array_equal(np.float64(r1[k]),
                                          np.float64(r2[k]),
                                          equal_nan=True) for k in r1)
                   for r1, r2 in zip(h1, h2)) and len(h1) == len(h2)

    out = {}
    # acceptance: flat 4-worker allreduce/bsp == (1,4) nesting == (2,2)
    # hierarchical intra+inter allreduce, bitwise
    s_flat, h_flat = fit(DistPlan.flat(4))
    s_14, h_14 = fit(DistPlan(axes=(AxisSpec("hosts", 1),
                                    AxisSpec("workers", 4))))
    s_22, h_22 = fit(DistPlan.grid(2, 2))
    out["parity"] = {
        "flat_vs_1x4": bitwise(s_flat, s_14) and hist_eq(h_flat, h_14),
        "flat_vs_2x2": bitwise(s_flat, s_22) and hist_eq(h_flat, h_22)}
    # hierarchical combos: inter-host collective x per-axis sync
    for inter, isync in itertools.product(("ps", "gossip"),
                                          ("bsp", "asp", "ssp")):
        plan = DistPlan.grid(2, 2, inter=inter, intra="allreduce",
                             inter_sync=isync, intra_sync="asp",
                             max_delay=2)
        _, hist = fit(plan)
        out[f"2x2/{inter}/{isync}"] = {
            "loss": hist[-1]["loss"], "ret": hist[-1]["episode_return"],
            "finite": (all(math.isfinite(r["loss"]) for r in hist)
                       and math.isfinite(hist[-1]["episode_return"]))}
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def plan_matrix_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _PLAN_MATRIX_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_plan_matrix_flat_vs_nested_bitwise_parity(plan_matrix_results):
    """Acceptance: a (hosts=2, workers=2) plan with intra-host allreduce
    + inter-host allreduce under bsp trains bitwise-identically to the
    legacy flat 4-worker allreduce path (and so does the (1,4)
    nesting) — the hierarchy is purely descriptive."""
    assert plan_matrix_results["parity"]["flat_vs_1x4"]
    assert plan_matrix_results["parity"]["flat_vs_2x2"]


def test_plan_matrix_hierarchical_combos_train(plan_matrix_results):
    combos = [k for k in plan_matrix_results if k.startswith("2x2/")]
    assert len(combos) == 6
    for combo in combos:
        res = plan_matrix_results[combo]
        assert res["finite"], combo
        assert res["ret"] > 0, (combo, res)


# -------------------------------------------------------- CLI contract
def test_cli_a3c_with_topology_and_sync_flags():
    """Legacy flags survive and lower onto a 1-D plan; A3C is reachable
    from the CLI via the registry."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train", "--algo", "a3c",
         "--env", "cartpole", "--topology", "allreduce", "--sync", "asp",
         "--iters", "4", "--superstep", "2", "--n-envs", "8",
         "--unroll", "4", "--log-every", "2"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["algo"] == "a3c"
    assert out["plan"] == "workers=1:allreduce:asp"
    assert out["history"]


def test_cli_plan_flag_runs_hierarchical_mesh():
    """--plan parses the hierarchical grammar, forces enough fake
    devices before jax loads, and reports the plan + elastic shards."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--plan", "hosts=2:allreduce:bsp,workers=2:allreduce:bsp",
         "--actors", "8,16", "--iters", "4", "--superstep", "2",
         "--n-envs", "8", "--unroll", "4", "--log-every", "2"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 4
    assert out["plan"].startswith("hosts=2:allreduce:bsp,workers=2")
    assert out["actor_shards"] == [8, 16]
    assert out["history"]


def test_cli_rejects_unknown_topology():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--topology", "star"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=120)
    assert r.returncode != 0
    assert "--topology" in r.stderr


def test_cli_rejects_malformed_plan():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--plan", "workers:4"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=120)
    assert r.returncode != 0
    assert "plan" in r.stderr.lower()


# ------------------------------------------- learning sanity (migrated)
def test_impala_policy_lag_vtrace_beats_naive():
    """Survey §6.1: under policy lag, V-trace correction must not be
    worse than the uncorrected learner (measured by final return)."""
    env = CartPole()
    rets = {}
    for use_vtrace in (True, False):
        cfg = TrainerConfig(algo="impala", iters=40, superstep=10,
                            n_envs=16, unroll=16, policy_lag=4, seed=3,
                            log_every=40,
                            algo_kwargs={"hidden": (32,),
                                         "use_vtrace": use_vtrace})
        _, hist = Trainer(env, cfg).fit()
        rets[use_vtrace] = hist[-1]["episode_return"]
    assert rets[True] >= 0.6 * rets[False], rets


def test_dqn_improves_on_gridworld():
    env = GridWorld(n=4, max_steps=16)
    cfg = TrainerConfig(algo="dqn", iters=60, superstep=10, n_envs=16,
                        unroll=8, log_every=20,
                        algo_kwargs={"warmup": 5, "eps_decay_steps": 40,
                                     "target_update": 20})
    _, hist = Trainer(env, cfg).fit()
    assert hist[-1]["episode_return"] > hist[0]["episode_return"], hist
