"""Unified Agent/Trainer API under the Distribution Plan API: registry
round-trip, fused-vs-unfused equivalence, the (collective x sync) smoke
matrix as 1-D plans on a fake 4-device mesh, the hierarchical 2-D plan
matrix on 8 fake devices (incl. flat-vs-nested bitwise parity), the
ZeRO shard-axis bitwise-parity matrix (all four algorithms), elastic
actor shards, CLI contract, and the learning-sanity claims."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import agent as agent_api
from repro.core.distribution import DistPlan
from repro.core.trainer import Trainer, TrainerConfig
from repro.envs import CartPole, GridWorld

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ALGOS = ("a3c", "dqn", "impala", "ppo")


# ------------------------------------------------------------- registry
def test_registry_lists_all_algorithms():
    assert set(ALGOS) <= set(agent_api.available())


@pytest.mark.parametrize("name", ALGOS)
def test_registry_roundtrip(name):
    """Every algorithm constructs by name, inits a TrainState pytree,
    and serves behavior params for any (clipped) delay."""
    env = CartPole()
    ag = agent_api.make(name, env=env, ring_size=3)
    state = ag.init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(state)
    assert isinstance(jax.tree_util.tree_unflatten(treedef, leaves),
                      agent_api.TrainState)
    fresh = ag.actor_policy(state, 0)
    stale = ag.actor_policy(state, 99)  # clipped to ring depth
    for a, b in zip(jax.tree_util.tree_leaves(fresh),
                    jax.tree_util.tree_leaves(stale)):
        assert a.shape == b.shape
        np.testing.assert_allclose(a, b)  # init: whole ring identical


def test_unknown_algo_raises():
    with pytest.raises(KeyError, match="unknown algorithm"):
        agent_api.make("nope", env=CartPole())


def test_ring_rotation_tracks_policy_lag():
    """After one learner step, delay-0 params are the new ones and
    delay-1 params are the previous ones."""
    env = CartPole()
    ag = agent_api.make("impala", env=env, ring_size=2,
                        hidden=(8,))
    state = ag.init(jax.random.PRNGKey(0))
    old = state.params
    key = jax.random.PRNGKey(1)
    env_state = env.reset_batch(key, 4)
    from repro.core.rollout import rollout
    traj, env_state = rollout(ag.policy, ag.actor_policy(state, 0), env,
                              key, env_state, 4)
    boot = jax.vmap(env.obs)(env_state)
    state, metrics = ag.learner_step(state, traj, boot, key)
    assert jnp.isfinite(metrics["loss"])
    lagged = ag.actor_policy(state, 1)
    for a, b in zip(jax.tree_util.tree_leaves(lagged),
                    jax.tree_util.tree_leaves(old)):
        np.testing.assert_allclose(a, b)
    newest = ag.actor_policy(state, 0)
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(newest),
        jax.tree_util.tree_leaves(old)))
    assert diff > 0


# --------------------------------------------------- episode accounting
def test_episode_accounting_exact_and_carried():
    """The episode_return metric is the mean return of episodes that
    COMPLETED this iteration; the per-env accumulator carries across
    iteration boundaries and zero-completion iterations report the last
    known value (NaN before any episode ever finished)."""
    run0 = jnp.zeros((2,))
    nan = jnp.full((), jnp.nan)
    rew = jnp.ones((3, 2))
    none_done = jnp.zeros((3, 2), bool)
    # iteration 1: nothing finishes -> NaN, accumulators keep counting
    run, ret = Trainer._episode_stats(run0, nan, {"reward": rew,
                                                  "done": none_done})
    assert np.isnan(float(ret))
    np.testing.assert_allclose(run, [3.0, 3.0])
    # iteration 2: env0 finishes at t=1 (episode return 3+1+1=5) and
    # restarts; env1 keeps running
    done = jnp.array([[False, False], [True, False], [False, False]])
    run, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                                 "done": done})
    assert float(ret) == pytest.approx(5.0)
    np.testing.assert_allclose(run, [1.0, 6.0])
    # iteration 3: nothing finishes -> last value carried, not a raw
    # sum; the accumulators keep growing ([1,6] + 3 steps of reward)
    run, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                                 "done": none_done})
    assert float(ret) == pytest.approx(5.0)
    np.testing.assert_allclose(run, [4.0, 9.0])
    # two completions in one block -> mean of both episode returns
    done2 = jnp.array([[True, True], [False, False], [False, False]])
    _, ret = Trainer._episode_stats(run, ret, {"reward": rew,
                                               "done": done2})
    assert float(ret) == pytest.approx(((4 + 1) + (9 + 1)) / 2)


# (the DistPlan schema unit tests — parse round-trips incl. the shard
# role grammar, validation errors, delay schedules — live in
# tests/test_distribution.py)


# ------------------------------------------- fused superstep equivalence
def test_fused_superstep_equals_unfused():
    """Acceptance: K fused iterations in one scan produce the same
    params and metrics as per-iteration dispatch for a fixed seed."""
    env = CartPole()

    def run(fused):
        cfg = TrainerConfig(algo="impala", iters=8, superstep=4,
                            n_envs=8, unroll=8, log_every=4, seed=1,
                            algo_kwargs={"hidden": (16,)})
        return Trainer(env, cfg).fit(fused=fused)

    s_fused, h_fused = run(True)
    s_unfused, h_unfused = run(False)
    for a, b in zip(jax.tree_util.tree_leaves(s_fused.params),
                    jax.tree_util.tree_leaves(s_unfused.params)):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)
    assert [r["iter"] for r in h_fused] == [r["iter"] for r in h_unfused]
    for rf, ru in zip(h_fused, h_unfused):
        assert rf["loss"] == pytest.approx(ru["loss"], rel=1e-3)


# -------------------------------------------------- elastic actor shards
def _hist_equal(h1, h2):
    """Bitwise history comparison; NaN (pre-first-episode) == NaN."""
    if len(h1) != len(h2):
        return False
    for r1, r2 in zip(h1, h2):
        if r1.keys() != r2.keys():
            return False
        for k in r1:
            if not np.array_equal(np.float64(r1[k]), np.float64(r2[k]),
                                  equal_nan=True):
                return False
    return True


def test_plan_elastic_actors_vary_shards_deterministically():
    """plan.actors cycles the env-shard count per superstep window; the
    per-shape numerics are pinned: two identical runs agree bitwise,
    the shard trace is exactly the schedule, and the unfused fit
    reshards at the same iteration boundaries (same numerics, one
    schedule entry per cfg.superstep iterations)."""
    env = CartPole()

    def run(fused=True):
        cfg = TrainerConfig(algo="impala", iters=9, superstep=3,
                            n_envs=8, unroll=6, log_every=1, seed=2,
                            plan=DistPlan.flat(1, actors=(8, 4, 8)),
                            algo_kwargs={"hidden": (8,)})
        tr = Trainer(env, cfg)
        state, hist = tr.fit(fused=fused)
        return state, hist, tr.actor_shards

    s1, h1, shards1 = run()
    s2, h2, shards2 = run()
    assert shards1 == [8, 4, 8] and shards2 == shards1
    assert _hist_equal(h1, h2)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s3, h3, shards3 = run(fused=False)
    assert shards3 == [8] * 3 + [4] * 3 + [8] * 3  # per-dispatch trace
    assert _hist_equal(h3, h1)
    for a, b in zip(jax.tree_util.tree_leaves(s3.params),
                    jax.tree_util.tree_leaves(s1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_constant_actors_schedule_is_bitwise_noop():
    """A constant actors= schedule equal to n_envs never reshards and
    is bitwise the plain run — elasticity is invisible to the agent."""
    env = CartPole()

    def run(plan):
        cfg = TrainerConfig(algo="impala", iters=6, superstep=3,
                            n_envs=8, unroll=6, log_every=1, seed=0,
                            plan=plan, algo_kwargs={"hidden": (8,)})
        tr = Trainer(env, cfg)
        state, hist = tr.fit()
        return state, hist

    s_c, h_c = run(DistPlan.flat(1, actors=(8,)))
    s_p, h_p = run(None)
    assert _hist_equal(h_c, h_p)
    for a, b in zip(jax.tree_util.tree_leaves(s_c.params),
                    jax.tree_util.tree_leaves(s_p.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------- collective x sync smoke (1-D plans, 4 devs)
_MATRIX_SCRIPT = textwrap.dedent("""
    import itertools, json, math
    import repro.envs as envs
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig
    env = envs.make("cartpole")
    out = {}
    for coll, sync in itertools.product(("allreduce", "ps", "gossip"),
                                        ("bsp", "asp", "ssp")):
        plan = DistPlan.flat(4, collective=coll, sync=sync, max_delay=2)
        cfg = TrainerConfig(algo="impala", iters=6, superstep=3,
                            n_envs=8, unroll=8, plan=plan,
                            log_every=2, algo_kwargs={"hidden": (8,)})
        _, hist = Trainer(env, cfg).fit()
        last = hist[-1]
        # episode_return is NaN until the first episode completes (the
        # honest boundary accounting) — require losses always finite
        # and the final return real
        out[f"{coll}/{sync}"] = {
            "loss": last["loss"], "ret": last["episode_return"],
            "finite": (all(math.isfinite(r["loss"]) for r in hist)
                       and math.isfinite(last["episode_return"]))}
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def matrix_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _MATRIX_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_matrix_covers_all_combinations(matrix_results):
    assert len(matrix_results) == 9


def test_matrix_all_finite_and_nondegenerate(matrix_results):
    for combo, res in matrix_results.items():
        assert res["finite"], combo
        assert res["ret"] > 0, (combo, res)  # CartPole returns positive


def test_matrix_sync_topologies_agree(matrix_results):
    """ps and allreduce are mathematically identical aggregations — the
    same training run must come out (numerically) the same."""
    for sync in ("bsp", "asp", "ssp"):
        a = matrix_results[f"allreduce/{sync}"]["loss"]
        p = matrix_results[f"ps/{sync}"]["loss"]
        assert a == pytest.approx(p, rel=1e-3), (sync, a, p)


# ----------------------- hierarchical 2-D plan matrix (8 fake devices)
_PLAN_MATRIX_SCRIPT = textwrap.dedent("""
    import itertools, json, math
    import jax, numpy as np
    import repro.envs as envs
    from repro.core.distribution import AxisSpec, DistPlan
    from repro.core.trainer import Trainer, TrainerConfig
    env = envs.make("cartpole")

    def fit(plan):
        cfg = TrainerConfig(algo="impala", iters=6, superstep=3,
                            n_envs=8, unroll=8, plan=plan,
                            log_every=1, seed=0,
                            algo_kwargs={"hidden": (8,)})
        return Trainer(env, cfg).fit()

    def bitwise(s1, s2):
        return all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                                   jax.tree_util.tree_leaves(s2.params)))

    def hist_eq(h1, h2):   # NaN-aware (pre-first-episode returns)
        return all(r1.keys() == r2.keys()
                   and all(np.array_equal(np.float64(r1[k]),
                                          np.float64(r2[k]),
                                          equal_nan=True) for k in r1)
                   for r1, r2 in zip(h1, h2)) and len(h1) == len(h2)

    out = {}
    # acceptance: flat 4-worker allreduce/bsp == (1,4) nesting == (2,2)
    # hierarchical intra+inter allreduce, bitwise
    s_flat, h_flat = fit(DistPlan.flat(4))
    s_14, h_14 = fit(DistPlan(axes=(AxisSpec("hosts", 1),
                                    AxisSpec("workers", 4))))
    s_22, h_22 = fit(DistPlan.grid(2, 2))
    out["parity"] = {
        "flat_vs_1x4": bitwise(s_flat, s_14) and hist_eq(h_flat, h_14),
        "flat_vs_2x2": bitwise(s_flat, s_22) and hist_eq(h_flat, h_22)}
    # hierarchical combos: inter-host collective x per-axis sync
    for inter, isync in itertools.product(("ps", "gossip"),
                                          ("bsp", "asp", "ssp")):
        plan = DistPlan.grid(2, 2, inter=inter, intra="allreduce",
                             inter_sync=isync, intra_sync="asp",
                             max_delay=2)
        _, hist = fit(plan)
        out[f"2x2/{inter}/{isync}"] = {
            "loss": hist[-1]["loss"], "ret": hist[-1]["episode_return"],
            "finite": (all(math.isfinite(r["loss"]) for r in hist)
                       and math.isfinite(hist[-1]["episode_return"]))}
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def plan_matrix_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _PLAN_MATRIX_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
def test_plan_matrix_flat_vs_nested_bitwise_parity(plan_matrix_results):
    """Acceptance: a (hosts=2, workers=2) plan with intra-host allreduce
    + inter-host allreduce under bsp trains bitwise-identically to the
    legacy flat 4-worker allreduce path (and so does the (1,4)
    nesting) — the hierarchy is purely descriptive."""
    assert plan_matrix_results["parity"]["flat_vs_1x4"]
    assert plan_matrix_results["parity"]["flat_vs_2x2"]


@pytest.mark.slow
def test_plan_matrix_hierarchical_combos_train(plan_matrix_results):
    combos = [k for k in plan_matrix_results if k.startswith("2x2/")]
    assert len(combos) == 6
    for combo in combos:
        res = plan_matrix_results[combo]
        assert res["finite"], combo
        assert res["ret"] > 0, (combo, res)


# ------------- ZeRO shard-axis bitwise parity (all four algorithms,
# 8 fake devices): a size-1 shard axis is a no-op vs today's trainer,
# and a size-2 sharded fit — after its in-step all-gather — matches the
# flat replicated plan f32-bitwise. opt_state moments at size 2 may
# drift by codegen ulps (FMA contraction differs between the vector-
# chunk and tree-shaped programs) while the params they produce stay
# bitwise, so size-2 pins params/ring/history and size-1 additionally
# pins the (reassembled, tree-shaped) opt_state.
_SHARD_PARITY_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    import repro.envs as envs
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig

    env = envs.make("cartpole")
    KW = {"a3c": {"hidden": (8,)}, "impala": {"hidden": (8,)},
          "ppo": {"hidden": (8,)},
          "dqn": {"hidden": (8,), "replay_capacity": 512, "warmup": 1}}

    def fit(algo, plan):
        cfg = TrainerConfig(algo=algo, iters=4, superstep=2, n_envs=8,
                            unroll=6, plan=plan, log_every=1, seed=0,
                            algo_kwargs=KW[algo])
        return Trainer(env, cfg).fit()

    def eq(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.shape != b.shape or a.dtype != b.dtype:
            return False
        if a.dtype.kind == "f":
            return bool(np.array_equal(a, b, equal_nan=True))
        return bool(np.array_equal(a, b))

    def bitwise(t1, t2):
        l1 = jax.tree_util.tree_leaves(t1)
        l2 = jax.tree_util.tree_leaves(t2)
        return len(l1) == len(l2) and all(eq(a, b)
                                          for a, b in zip(l1, l2))

    def hist_eq(h1, h2):
        return len(h1) == len(h2) and all(
            r1.keys() == r2.keys() and all(
                np.array_equal(np.float64(r1[k]), np.float64(r2[k]),
                               equal_nan=True) for k in r1)
            for r1, r2 in zip(h1, h2))

    out = {}
    for algo in ("a3c", "dqn", "impala", "ppo"):
        s4, h4 = fit(algo, DistPlan.flat(4))
        s41, h41 = fit(algo, DistPlan.parse(
            "workers=4:allreduce:bsp,shard=1:allreduce:bsp:shard"))
        s8, h8 = fit(algo, DistPlan.flat(8))
        s42, h42 = fit(algo, DistPlan.zero(4, 2))
        out[algo] = {
            "size1_params": bitwise(s4.params, s41.params),
            "size1_opt": bitwise(s4.opt_state, s41.opt_state),
            "size1_ring": bitwise(s4.ring, s41.ring),
            "size1_hist": hist_eq(h4, h41),
            "size2_params": bitwise(s8.params, s42.params),
            "size2_ring": bitwise(s8.ring, s42.ring),
            "size2_hist": hist_eq(h8, h42)}
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def shard_parity_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _SHARD_PARITY_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("algo", ALGOS)
def test_shard_axis_size1_is_bitwise_noop(shard_parity_results, algo):
    """Acceptance: appending a size-1 shard axis to the flat 4-worker
    plan trains bitwise-identically to today's trainer — params,
    opt_state (tree-shaped, by the size-1 short-circuit), actor ring
    and metric history all match exactly."""
    res = shard_parity_results[algo]
    for key in ("size1_params", "size1_opt", "size1_ring", "size1_hist"):
        assert res[key], (algo, key, res)


@pytest.mark.slow
@pytest.mark.parametrize("algo", ALGOS)
def test_shard_axis_size2_matches_replicated_after_allgather(
        shard_parity_results, algo):
    """Acceptance: a (workers=4, shard=2) ZeRO plan — reduce-scatter,
    1/2-slice optimizer update, all-gather — produces f32-bitwise the
    params (and actor ring and history) of the flat replicated
    8-worker plan on the same 8 devices."""
    res = shard_parity_results[algo]
    for key in ("size2_params", "size2_ring", "size2_hist"):
        assert res[key], (algo, key, res)


# ------------- ZeRO-3 (zero3-role axis) bitwise parity (all four
# algorithms, 8 fake devices): params are STORED sharded and gathered
# per use, so the fit must still match the flat replicated plan
# f32-bitwise on the MLP policy — gather(local_shard(vec)) is the
# identity on the padded flat params, and adamw keeps the zero padding
# zero. Size-2 pins params/ring/history (reassembled opt moments carry
# the same chunk-vs-tree codegen-ulp caveat as ZeRO-2); the size-1
# zero3 axis short-circuits to the unwrapped agent and additionally
# pins opt_state.
_ZERO3_PARITY_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    import repro.envs as envs
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig

    env = envs.make("cartpole")
    KW = {"a3c": {"hidden": (8,)}, "impala": {"hidden": (8,)},
          "ppo": {"hidden": (8,)},
          "dqn": {"hidden": (8,), "replay_capacity": 512, "warmup": 1}}

    def fit(algo, plan):
        cfg = TrainerConfig(algo=algo, iters=4, superstep=2, n_envs=8,
                            unroll=6, plan=plan, log_every=1, seed=0,
                            algo_kwargs=KW[algo])
        return Trainer(env, cfg).fit()

    def eq(a, b):
        a, b = np.asarray(a), np.asarray(b)
        if a.shape != b.shape or a.dtype != b.dtype:
            return False
        return bool(np.array_equal(a, b, equal_nan=a.dtype.kind == "f"))

    def bitwise(t1, t2):
        l1 = jax.tree_util.tree_leaves(t1)
        l2 = jax.tree_util.tree_leaves(t2)
        return len(l1) == len(l2) and all(eq(a, b)
                                          for a, b in zip(l1, l2))

    def hist_eq(h1, h2):
        return len(h1) == len(h2) and all(
            r1.keys() == r2.keys() and all(
                np.array_equal(np.float64(r1[k]), np.float64(r2[k]),
                               equal_nan=True) for k in r1)
            for r1, r2 in zip(h1, h2))

    out = {}
    for algo in ("a3c", "dqn", "impala", "ppo"):
        s4, h4 = fit(algo, DistPlan.flat(4))
        s41, h41 = fit(algo, DistPlan.parse(
            "workers=4:allreduce:bsp,shard=1:allreduce:bsp:zero3"))
        s8, h8 = fit(algo, DistPlan.flat(8))
        s42, h42 = fit(algo, DistPlan.zero3(4, 2))
        out[algo] = {
            "size1_params": bitwise(s4.params, s41.params),
            "size1_opt": bitwise(s4.opt_state, s41.opt_state),
            "size1_ring": bitwise(s4.ring, s41.ring),
            "size1_hist": hist_eq(h4, h41),
            "size2_params": bitwise(s8.params, s42.params),
            "size2_ring": bitwise(s8.ring, s42.ring),
            "size2_hist": hist_eq(h8, h42)}
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def zero3_parity_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _ZERO3_PARITY_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("algo", ALGOS)
def test_zero3_axis_size1_is_bitwise_noop(zero3_parity_results, algo):
    """Acceptance: a size-1 zero3 axis appended to the flat 4-worker
    plan is a bitwise no-op — params, opt_state, actor ring and metric
    history all match today's trainer exactly."""
    res = zero3_parity_results[algo]
    for key in ("size1_params", "size1_opt", "size1_ring", "size1_hist"):
        assert res[key], (algo, key, res)


@pytest.mark.slow
@pytest.mark.parametrize("algo", ALGOS)
def test_zero3_size2_matches_replicated_bitwise(zero3_parity_results,
                                                algo):
    """Acceptance: a (workers=4, shard=2:zero3) plan — params stored as
    1/2 chunks, all-gathered per use inside learner_step and
    actor_policy — produces f32-bitwise the params, actor ring and
    history of the flat replicated 8-worker plan on the same devices,
    for all four algorithms."""
    res = zero3_parity_results[algo]
    for key in ("size2_params", "size2_ring", "size2_hist"):
        assert res[key], (algo, key, res)


# -------------------------------------------------------- CLI contract
def test_cli_a3c_with_topology_and_sync_flags():
    """Legacy flags survive and lower onto a 1-D plan; A3C is reachable
    from the CLI via the registry."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train", "--algo", "a3c",
         "--env", "cartpole", "--topology", "allreduce", "--sync", "asp",
         "--iters", "4", "--superstep", "2", "--n-envs", "8",
         "--unroll", "4", "--log-every", "2"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["algo"] == "a3c"
    assert out["plan"] == "workers=1:allreduce:asp"
    assert out["history"]


def test_cli_plan_flag_runs_hierarchical_mesh():
    """--plan parses the hierarchical grammar, forces enough fake
    devices before jax loads, and reports the plan + elastic shards."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--plan", "hosts=2:allreduce:bsp,workers=2:allreduce:bsp",
         "--actors", "8,16", "--iters", "4", "--superstep", "2",
         "--n-envs", "8", "--unroll", "4", "--log-every", "2"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 4
    assert out["plan"].startswith("hosts=2:allreduce:bsp,workers=2")
    assert out["actor_shards"] == [8, 16]
    assert out["history"]


def test_cli_rejects_unknown_topology():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--topology", "star"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=120)
    assert r.returncode != 0
    assert "--topology" in r.stderr


def test_cli_rejects_malformed_plan():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--plan", "workers:4"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=120)
    assert r.returncode != 0
    assert "plan" in r.stderr.lower()


def test_cli_plan_zero3_role_round_trips_and_reports_partition():
    """--plan accepts a zero3-role axis, trains through the wrapped
    agent, and the output JSON echoes the plan verbatim plus the
    resolved ZeRO partition (axis, shard count, chunk sizes)."""
    spec = "workers=2:allreduce:bsp,shard=2:allreduce:bsp:zero3"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--plan", spec, "--iters", "4", "--superstep", "2",
         "--n-envs", "8", "--unroll", "4", "--log-every", "2"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["plan"] == spec
    assert out["n_devices"] == 4
    assert out["partition"]["n_shards"] == 2
    assert out["partition"]["axis"] == "shard"
    assert out["partition"]["chunk"] * 2 == out["partition"]["padded"]
    assert out["history"]


def test_cli_rejects_zero3_on_wrong_collective_naming_segment():
    """A zero3 axis on a non-allreduce collective dies in DistPlan
    validation with an error naming the offending axis."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train",
         "--plan", "workers=2:allreduce:bsp,s=2:gossip:bsp:zero3"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=120)
    assert r.returncode != 0
    assert "'s'" in r.stderr and "allreduce" in r.stderr


# ------------------------------------------- learning sanity (migrated)
def test_impala_policy_lag_vtrace_beats_naive():
    """Survey §6.1: under policy lag, V-trace correction must not be
    worse than the uncorrected learner (measured by final return)."""
    env = CartPole()
    rets = {}
    for use_vtrace in (True, False):
        cfg = TrainerConfig(algo="impala", iters=40, superstep=10,
                            n_envs=16, unroll=16, policy_lag=4, seed=3,
                            log_every=40,
                            algo_kwargs={"hidden": (32,),
                                         "use_vtrace": use_vtrace})
        _, hist = Trainer(env, cfg).fit()
        rets[use_vtrace] = hist[-1]["episode_return"]
    assert rets[True] >= 0.6 * rets[False], rets


def test_dqn_improves_on_gridworld():
    """Late-training return must clear a near-optimal absolute bar.

    The first logged entry is NOT a random-policy baseline: iteration 0
    averages only the episodes that happen to finish inside the first
    unroll (lucky near-goal starts), so it reads ~0.96-0.98 while the
    true exploration-phase return — visible mid-history once longer
    episodes complete — sits near 0 or below. Comparing final vs first
    is therefore meaningless; instead assert the converged policy
    (eps annealed to its floor) reliably navigates to the goal, which a
    non-learning policy at the same epsilon cannot (it times out at
    ~-0.16 per episode)."""
    env = GridWorld(n=4, max_steps=16)
    cfg = TrainerConfig(algo="dqn", iters=100, superstep=10, n_envs=16,
                        unroll=8, log_every=10,
                        algo_kwargs={"warmup": 5, "eps_decay_steps": 60,
                                     "target_update": 20})
    _, hist = Trainer(env, cfg).fit()
    late = [h["episode_return"] for h in hist[-2:]]
    assert sum(late) / len(late) > 0.9, hist
