"""Bench-schema guard: every repo-root BENCH_*.json must parse against
the repro-bench/v1 shape (benchmarks/common.validate_bench_json), so
the machine-readable perf trajectory can't silently rot; plus the
pinned headlines: BENCH_zero.json (per-device opt_state bytes shrink
~1/shard_size under the ZeRO-2 shard axis; params+opt <= 0.67x under
the ZeRO-3 axis on the transformer trunk; peak live bytes strictly
below replicated under the layer-wise gather), BENCH_hotpath.json
(attention seam rows), BENCH_pipeline.json (every pipelined depth
beats decoupled-serial), BENCH_serve.json (sane p50/p99 grid, zero
recompiles after warmup across hot-swaps), and BENCH_replay.json
(per-device replay bytes <= 0.67x under the 2-shard replay axis)."""
import glob
import json
import os
import subprocess
import sys

import pytest

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.abspath(REPO_ROOT))

from benchmarks.common import SCHEMA, validate_bench_json  # noqa: E402

BENCH_FILES = sorted(glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json")))


def test_bench_files_exist():
    names = {os.path.basename(p) for p in BENCH_FILES}
    # the committed trajectory: hot path (PR 3), topologies/sync (PR 4),
    # learner sharding (PR 5), actor-learner pipeline (PR 6),
    # policy serving (PR 7), sharded replay (PR 9)
    assert {"BENCH_hotpath.json", "BENCH_topologies.json",
            "BENCH_sync.json", "BENCH_zero.json",
            "BENCH_pipeline.json", "BENCH_serve.json",
            "BENCH_replay.json"} <= names


@pytest.mark.parametrize("path", BENCH_FILES,
                         ids=[os.path.basename(p) for p in BENCH_FILES])
def test_bench_json_matches_schema(path):
    with open(path) as f:
        doc = json.load(f)
    validate_bench_json(doc)
    assert doc["schema"] == SCHEMA
    assert doc["rows"], f"{path} has no rows"


def test_validate_bench_json_names_offending_input():
    good = {"schema": SCHEMA, "benchmark": "x", "backend": "cpu",
            "meta": {}, "rows": [{"name": "a", "us_per_call": 1.0,
                                  "derived": "d"}]}
    validate_bench_json(good)  # sanity: the good doc passes
    for mutate, frag in [
            (lambda d: d.update(schema="v2"), "schema"),
            (lambda d: d.update(rows="nope"), "rows"),
            (lambda d: d.update(meta=None), "meta"),
            (lambda d: d["rows"].append({"name": 1}), "rows[1]"),
            (lambda d: d["rows"][0].update(derived=7), "derived")]:
        doc = json.loads(json.dumps(good))
        mutate(doc)
        with pytest.raises(ValueError) as e:
            validate_bench_json(doc)
        assert frag in str(e.value), (frag, str(e.value))


def test_zero_bench_pins_opt_state_shrink():
    """Acceptance: BENCH_zero.json records per-device opt_state live
    bytes shrinking ~1/shard_size (within flatten-and-pad padding) for
    the size-2 shard axis vs the replicated plan."""
    with open(os.path.join(REPO_ROOT, "BENCH_zero.json")) as f:
        doc = validate_bench_json(json.load(f))
    row = {r["name"]: r["derived"] for r in doc["rows"]}
    derived = row["zero2/opt_state_shrink"]
    kv = dict(item.split("=", 1) for item in derived.split(";"))
    n_shards = doc["meta"]["partition"]["n_shards"]
    ratio = float(kv["ratio"])
    # ~1/shard_size within padding (one padded f32 out of the chunk)
    assert abs(ratio - 1.0 / n_shards) < 0.01, derived
    assert kv["ideal"] == f"1/{n_shards}"
    # and XLA's compiled live-bytes agree the sharded plan is smaller
    assert int(kv["xla_live_saved_bytes"]) > 0, derived


def test_zero_bench_pins_zero3_param_state_shrink():
    """Acceptance (PR 8): BENCH_zero.json records per-device
    params+opt_state bytes under the zero3-role axis at <= 0.67x the
    replicated plan on the transformer trunk (each component ~1/n_shards
    within padding), with XLA argument bytes — the persistent state the
    compiled superstep carries — corroborating. Live bytes are recorded
    too; gather-per-use converts the persistent saving into transient
    temp traffic, so that delta may go either way at 2 shards."""
    with open(os.path.join(REPO_ROOT, "BENCH_zero.json")) as f:
        doc = validate_bench_json(json.load(f))
    rows = {r["name"]: r for r in doc["rows"]}
    kv = dict(item.split("=", 1) for item in
              rows["zero3/param_state_shrink"]["derived"].split(";"))
    n = int(kv["n_shards"])
    assert n == doc["meta"]["partition_zero3"]["n_shards"]
    assert float(kv["threshold"]) == 0.67
    assert float(kv["ratio"]) <= 0.67, kv
    assert abs(float(kv["params_ratio"]) - 1.0 / n) < 0.01, kv
    assert abs(float(kv["opt_ratio"]) - 1.0 / n) < 0.01, kv
    assert int(kv["xla_arg_saved_bytes"]) > 0, kv
    int(kv["xla_live_saved_bytes"])  # present and integral
    for name in ("zero_shard/replicated_trunk", "zero_shard/zero3_trunk"):
        assert rows[name]["us_per_call"] > 0, name
        assert "xla_arg_bytes=" in rows[name]["derived"], name


def test_zero_bench_pins_layerwise_peak_live_shrink():
    """Acceptance (PR 10): with the per-block partition list (gather →
    run → drop one trunk superblock at a time, plus the per-entry
    optimizer apply), XLA peak LIVE bytes — argument + output + temp −
    donated alias of the compiled superstep — at 2 shards land strictly
    BELOW the replicated plan on the transformer trunk. This is the row
    the whole-vector gather could never produce: its full-size temps
    offset the argument saving at any shard count. Holds for the
    committed full run and the --quick regeneration CI does before this
    test."""
    with open(os.path.join(REPO_ROOT, "BENCH_zero.json")) as f:
        doc = validate_bench_json(json.load(f))
    rows = {r["name"]: r for r in doc["rows"]}
    kv = dict(item.split("=", 1) for item in
              rows["zero3_layerwise/peak_live_shrink"]["derived"].split(";"))
    assert float(kv["threshold"]) == 0.95
    assert float(kv["live_ratio"]) <= 0.95, kv
    assert (int(kv["xla_live_bytes_zero3"])
            < int(kv["xla_live_bytes_replicated"])), kv
    assert int(kv["xla_live_saved_bytes"]) > 0, kv
    # the trunk partitions layer-wise: R superblocks + the remainder
    assert int(kv["entries"]) >= 2, kv
    part = doc["meta"]["partition_zero3"]
    assert part["listwise"] is True, part
    assert part["entries"] == int(kv["entries"])
    assert len(part["sizes"]) == part["entries"]
    assert sum(part["sizes"]) == part["size"], part


def test_committed_bench_files_are_full_mode():
    """The committed perf trajectory must be full-mode runs: every
    BENCH_*.json blob at HEAD whose meta carries the `quick` stamp must
    have it False. CI regenerates the working-tree files with --quick
    before running tests, so this guard reads `git show HEAD:<file>` —
    the committed state — not the (legitimately quick) working tree.
    Files written before the stamp existed pass (key absent)."""
    for path in BENCH_FILES:
        rel = os.path.relpath(path, REPO_ROOT)
        proc = subprocess.run(
            ["git", "show", f"HEAD:{rel}"], cwd=REPO_ROOT,
            capture_output=True, text=True)
        if proc.returncode != 0:
            continue  # new file not yet at HEAD (or not a git checkout)
        doc = json.loads(proc.stdout)
        assert doc.get("meta", {}).get("quick") is not True, (
            f"{rel} was committed from a --quick run; regenerate it "
            f"with the full benchmark before committing")


def test_replay_bench_pins_bytes_shrink():
    """Acceptance (PR 9): BENCH_replay.json records per-device replay
    bytes under the 2-shard replay-role axis at <= 0.67x the replicated
    plan (ideal 1/2: each member owns one contiguous half of the ONE
    logical buffer), with XLA argument bytes — the persistent state the
    compiled superstep carries — corroborating, plus the per-sample
    latency rows for the flat fused draw vs the sharded merge. Holds
    for the committed full run and the --quick regeneration CI does
    before this test."""
    with open(os.path.join(REPO_ROOT, "BENCH_replay.json")) as f:
        doc = validate_bench_json(json.load(f))
    rows = {r["name"]: r for r in doc["rows"]}
    kv = dict(item.split("=", 1) for item in
              rows["replay/replay_bytes_shrink"]["derived"].split(";"))
    part = doc["meta"]["partition_replay"]
    assert part["axis"] == "replay" and part["n_shards"] == 2
    assert part["chunk"] * part["n_shards"] == part["capacity"]
    assert float(kv["threshold"]) == 0.67
    assert float(kv["ratio"]) <= 0.67, kv
    assert kv["ideal"] == f"1/{part['n_shards']}"
    assert int(kv["chunk"]) == part["chunk"]
    assert int(kv["sharded_bytes"]) < int(kv["replicated_bytes"]), kv
    assert int(kv["xla_arg_saved_bytes"]) > 0, kv
    for name in ("replay_shard/replicated", "replay_shard/sharded",
                 "replay_sample/flat_fused", "replay_sample/sharded_merge"):
        assert name in rows, sorted(rows)
        assert rows[name]["us_per_call"] > 0, name
    assert "overhead_ratio=" in rows["replay_sample/sharded_merge"][
        "derived"]


def test_hotpath_bench_pins_attention_rows():
    """Acceptance (PR 8): BENCH_hotpath.json times the trunk's
    attention seam three ways — naive jnp full softmax, the
    core/attention.py dispatcher ref, and the Pallas flash kernel — in
    the (B, S, KVH, G, D) grouped-query layout. Holds for the committed
    full run and the --quick regeneration CI does before this test."""
    with open(os.path.join(REPO_ROOT, "BENCH_hotpath.json")) as f:
        doc = validate_bench_json(json.load(f))
    rows = {r["name"]: r for r in doc["rows"]}
    for name in ("attention/naive_jnp", "attention/flash_ref",
                 "attention/flash_kernel"):
        assert name in rows, sorted(rows)
        assert rows[name]["us_per_call"] > 0, name
        assert "S=" in rows[name]["derived"], name
    assert "full_softmax" in rows["attention/naive_jnp"]["derived"]
    assert "interpret=" in rows["attention/flash_kernel"]["derived"]


def test_pipeline_bench_pins_overlap_claim():
    """Acceptance: BENCH_pipeline.json records the pipelined superstep
    running strictly under the decoupled-serial rollout+learn sum
    (overlap_fraction > 0) for EVERY depth >= 1 cell — the reason the
    trajectory queue exists. Holds for the committed full run and for
    the --quick regeneration CI does before this test."""
    with open(os.path.join(REPO_ROOT, "BENCH_pipeline.json")) as f:
        doc = validate_bench_json(json.load(f))
    rows = {r["name"]: r for r in doc["rows"]}

    def kv(name):
        return dict(item.split("=", 1)
                    for item in rows[name]["derived"].split(";"))

    deep = [n for n in rows
            if n.startswith("pipeline/") and n[-2:] in ("d1", "d2")]
    assert len(deep) >= 4, sorted(rows)  # {ppo,dqn} x depths {1,2}
    for name in deep:
        d = kv(name)
        assert float(d["pipe_us"]) < float(d["serial_sum_us"]), (name, d)
        assert float(d["overlap_fraction"]) > 0, (name, d)
        assert int(d["capacity"]) == int(d["depth"]), (name, d)
    claim = kv("pipeline/overlap_claim")
    assert claim["all_below_serial"] == "True", claim
    assert float(claim["worst_overlap_fraction"]) > 0, claim


def test_serve_bench_pins_latency_grid_and_flat_compiles():
    """Acceptance: BENCH_serve.json covers a grid of >= 2 offered loads
    x >= 2 bucket configurations, each cell reporting sane latency
    percentiles (p99 > p50 > 0) and positive delivered throughput, and
    the serve/compile_flat row pins zero recompiles after warmup with
    at least one live hot-swap — holds for the committed full run and
    for the --quick regeneration CI does before this test."""
    with open(os.path.join(REPO_ROOT, "BENCH_serve.json")) as f:
        doc = validate_bench_json(json.load(f))
    rows = {r["name"]: r for r in doc["rows"]}

    def kv(name):
        return dict(item.split("=", 1)
                    for item in rows[name]["derived"].split(";"))

    cells = [n for n in rows if "/load" in n]
    assert len(cells) >= 4, sorted(rows)
    loads, configs = set(), set()
    for name in cells:
        d = kv(name)
        # serve/<algo>/b<cfg>/load<rps>
        configs.add(name.split("/")[2])
        loads.add(float(d["offered_rps"]))
        assert float(d["p99_ms"]) > float(d["p50_ms"]) > 0, (name, d)
        assert float(d["throughput_rps"]) > 0, (name, d)
        assert int(d["n"]) > 0, (name, d)
    assert len(loads) >= 2, loads
    assert len(configs) >= 2, configs
    flat = kv("serve/compile_flat")
    assert flat["recompiles_after_warmup"] == "0", flat
    assert int(flat["warmup_compiles"]) > 0, flat
    assert int(flat["hot_swaps"]) >= 1, flat
