"""Substrate tests: optimizers, data pipeline, checkpointing, envs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-fallback

from repro.data import TokenStream
from repro.checkpoint import save_checkpoint, load_checkpoint
from repro.optim import (adamw, sgd, lion, clip_by_global_norm,
                         cosine_schedule, global_norm)

SETTINGS = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------- optim
def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    p = {"w": jnp.array([5.0, -3.0])}
    st_ = opt.init(p)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(p)
        p, st_ = opt.apply(p, st_, g)
    np.testing.assert_allclose(p["w"], 1.0, atol=1e-2)


def test_sgd_momentum_matches_closed_form():
    opt = sgd(0.1, momentum=0.9)
    p = {"w": jnp.zeros(())}
    st_ = opt.init(p)
    g = {"w": jnp.ones(())}
    mu = 0.0
    w = 0.0
    for _ in range(5):
        p, st_ = opt.apply(p, st_, g)
        mu = 0.9 * mu + 1.0
        w = w - 0.1 * mu
    assert float(p["w"]) == pytest.approx(w, abs=1e-6)


@given(seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_lion_updates_are_sign_bounded(seed):
    """Lion property: per-coordinate update magnitude == lr (sign-based)."""
    key = jax.random.PRNGKey(seed)
    opt = lion(0.01)
    p = {"w": jax.random.normal(key, (8,))}
    st_ = opt.init(p)
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (8,))}
    upd, _ = opt.update(g, st_, p)
    assert bool(jnp.all(jnp.abs(upd["w"]) <= 0.01 + 1e-7))


@given(seed=st.integers(0, 100), max_norm=st.floats(0.1, 5.0))
@settings(**SETTINGS)
def test_clipping_bounds_global_norm(seed, max_norm):
    key = jax.random.PRNGKey(seed)
    grads = {"a": 10 * jax.random.normal(key, (16,)),
             "b": 10 * jax.random.normal(jax.random.fold_in(key, 1), (4,))}
    opt = clip_by_global_norm(sgd(1.0), max_norm)
    p = jax.tree_util.tree_map(jnp.zeros_like, grads)
    upd, _ = opt.update(grads, opt.init(p), p)
    # update = -lr * clipped grad => norm <= max_norm
    assert float(global_norm(upd)) <= max_norm * 1.001


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 100, warmup=10, floor=0.1)
    assert float(s(0)) == pytest.approx(0.0)
    assert float(s(10)) == pytest.approx(1.0, abs=1e-6)
    assert float(s(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(s(55)) < float(s(20))


# ----------------------------------------------------------------- data
def test_tokenstream_deterministic():
    s = TokenStream(vocab=97, seq_len=32, global_batch=8, seed=3)
    b1 = s.batch_at(5)
    b2 = s.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 33)
    assert int(b1["tokens"].max()) < 97


def test_tokenstream_sharding_partition():
    """Shards from different workers are disjoint deterministic slices
    whose union has the global batch size."""
    s = TokenStream(vocab=97, seq_len=16, global_batch=8, seed=0)
    shards = [s.shard_at(2, i, 4)["tokens"] for i in range(4)]
    assert all(sh.shape == (2, 17) for sh in shards)
    # deterministic
    np.testing.assert_array_equal(shards[1],
                                  s.shard_at(2, 1, 4)["tokens"])


def test_tokenstream_predictability():
    s = TokenStream(vocab=97, seq_len=256, global_batch=4, seed=0,
                    p_predictable=0.9)
    t = s.batch_at(0)["tokens"]
    frac = float(jnp.mean((t[:, 1:] - t[:, :-1]) % 97 == 1))
    assert 0.8 < frac < 0.97


# ----------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"params": {"w": jax.random.normal(rng, (4, 3)),
                       "layers": [{"b": jnp.arange(3.0)},
                                  {"b": jnp.arange(2.0)}]},
            "opt": {"step": jnp.int32(7)}}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree, step=42)
    restored, step = load_checkpoint(path, jax.eval_shape(lambda: tree))
    assert step == 42
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(a, b), tree, restored)


def test_checkpoint_through_training(tmp_path, rng):
    """Save/restore mid-training continues identically."""
    from repro.optim import adamw
    opt = adamw(0.1)
    p = {"w": jnp.array([3.0])}
    st_ = opt.init(p)
    g = {"w": jnp.array([1.0])}
    for _ in range(3):
        p, st_ = opt.apply(p, st_, g)
    path = os.path.join(tmp_path, "mid.npz")
    save_checkpoint(path, {"p": p, "s": st_})
    (restored, _) = load_checkpoint(path, jax.eval_shape(
        lambda: {"p": p, "s": st_}))
    p2, st2 = opt.apply(restored["p"], restored["s"], g)
    p1, _ = opt.apply(p, st_, g)
    np.testing.assert_allclose(p1["w"], p2["w"], atol=1e-7)


# ------------------------------------------------------------------ envs
# (the full env-API conformance suite lives in tests/test_env_api.py;
# these pin the seed-era compat surface: derived obs_dim/n_actions/
# act_dim attributes still drive a rollout)
@pytest.mark.parametrize("env_name", ["cartpole", "pendulum", "gridworld"])
def test_env_step_autoreset(env_name, rng):
    import repro.envs as envs
    env = envs.make(env_name)
    n = 8
    state = env.reset_batch(rng, n)
    for i in range(5):
        if env.n_actions:
            a = jax.random.randint(jax.random.fold_in(rng, i), (n,), 0,
                                   env.n_actions)
        else:
            a = jax.random.normal(jax.random.fold_in(rng, i),
                                  (n, env.act_dim))
        state, obs, r, d = env.step_autoreset(state, a,
                                              jax.random.fold_in(rng, i))
        assert obs.shape == (n, env.obs_dim)
        assert bool(jnp.all(jnp.isfinite(obs)))


def test_env_rollout_fully_jitted(rng):
    """Zero-copy property: the whole rollout compiles to ONE XLA program
    (no host callbacks in the jaxpr)."""
    import repro.envs as envs
    from repro.core.networks import MLPPolicy
    from repro.core.rollout import rollout
    env = envs.make("cartpole")
    pol = MLPPolicy(env.obs_dim, env.n_actions, hidden=(8,))
    params = pol.init(rng)
    state = env.reset_batch(rng, 4)
    jaxpr = jax.make_jaxpr(
        lambda p, k, s: rollout(pol, p, env, k, s, 8))(params, rng, state)
    assert "callback" not in str(jaxpr), "env must not round-trip host"
