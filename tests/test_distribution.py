"""Distribution Plan API schema tests: parse/describe round-trips
(incl. the `role` grammar and hypothesis property round-trips),
validation errors naming the offending input, delay schedules, the
flatten-and-pad partitioning + ZeRO sharded-optimizer math (under vmap
named axes, no mesh needed), and the --plan CLI error contract.

Absorbed the DistPlan schema unit tests that previously lived in
tests/test_trainer.py (the multi-device Trainer parity/smoke matrices
stay there — they spawn fake-device subprocesses)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-fallback

from repro.core.agent import flatten_and_pad
from repro.core.distribution import AxisSpec, DistPlan
from repro.core.topology import (all_gather_shards, local_shard,
                                 reduce_scatter_mean,
                                 zero_sharded_optimizer)
from repro.core.trainer import Trainer, TrainerConfig
from repro.envs import CartPole
from repro.optim import adamw, clip_by_global_norm

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
SETTINGS = dict(max_examples=25, deadline=None)


# ------------------------------------------- schema (from test_trainer)
def test_plan_defaults_to_flat_single_worker():
    plan = DistPlan.flat()
    assert plan.axis_names == ("workers",)
    assert plan.mesh_shape == (1,)
    assert plan.n_devices == 1 and plan.ring_extra == 0
    assert plan.shard_axis is None and plan.shard_size == 1


def test_plan_parse_round_trip():
    s = "hosts=2:allreduce:bsp,workers=4:gossip:asp"
    plan = DistPlan.parse(s, max_delay=3)
    assert plan.axis_names == ("hosts", "workers")
    assert plan.mesh_shape == (2, 4)
    assert plan.axes[1].collective == "gossip"
    assert plan.axes[1].sync == "asp"
    assert plan.describe() == s
    assert plan.ring_extra == 3  # bsp(0) + asp(max_delay=3)


def test_plan_ring_extra_adds_across_axes():
    plan = DistPlan(axes=(
        AxisSpec("hosts", 2, sync="asp", max_delay=5),
        AxisSpec("workers", 2, sync="ssp", max_delay=5,
                 staleness_bound=2)))
    assert plan.ring_extra == 5 + 2
    cfg = TrainerConfig(plan=plan, policy_lag=1)
    assert cfg.ring_size == 1 + 7 + 1


def test_plan_delay_schedule_adds_per_axis():
    plan = DistPlan(axes=(
        AxisSpec("hosts", 2, sync="asp", max_delay=3),
        AxisSpec("workers", 4, sync="bsp")))
    d = plan.make_delay_schedule(10, jax.random.PRNGKey(0))
    assert d.shape == (10, 2, 4)
    # bsp inner axis adds nothing: delays constant across workers
    np.testing.assert_array_equal(
        np.asarray(d),
        np.broadcast_to(np.asarray(d)[:, :, :1], d.shape))
    assert int(d.max()) <= 3


def test_plan_flat_delay_schedule_matches_legacy_sync():
    """The 1-D plan consumes the key exactly as sync.make_delays did —
    the legacy schedule is bitwise what the plan produces."""
    from repro.core.sync import SyncConfig, make_delays
    key = jax.random.PRNGKey(3)
    plan = DistPlan.flat(4, sync="ssp", max_delay=6, staleness_bound=2)
    legacy = make_delays(SyncConfig("ssp", 4, 6, 2), 20, key)
    np.testing.assert_array_equal(
        np.asarray(plan.make_delay_schedule(20, key)), np.asarray(legacy))


def test_plan_validation_errors():
    with pytest.raises(ValueError, match="collective"):
        AxisSpec("workers", 2, collective="star")
    with pytest.raises(ValueError, match="sync"):
        AxisSpec("workers", 2, sync="eventual")
    with pytest.raises(ValueError, match="duplicate"):
        DistPlan(axes=(AxisSpec("w", 2), AxisSpec("w", 2)))
    with pytest.raises(ValueError, match="actors"):
        DistPlan.flat(1, actors=(4, 0))
    with pytest.raises(ValueError, match="divide"):
        Trainer(CartPole(), TrainerConfig(n_envs=6,
                                          plan=DistPlan.flat(4)))
    with pytest.raises(ValueError, match="actors"):
        Trainer(CartPole(), TrainerConfig(
            n_envs=8, plan=DistPlan.flat(4, actors=(8, 6))))


def test_plan_device_validation_names_count_and_shape():
    """Requesting a plan shape larger than the visible device count must
    raise a clear error naming both — never silently slice devices."""
    with pytest.raises(RuntimeError) as e:
        Trainer(CartPole(), TrainerConfig(n_envs=64,
                                          plan=DistPlan.flat(64)))
    msg = str(e.value)
    assert "64 devices" in msg and "workers=64" in msg
    assert "xla_force_host_platform_device_count" in msg


# --------------------------------------------------- shard-role grammar
def test_plan_parse_shard_role_round_trip():
    s = "workers=4:allreduce:bsp,shard=2:allreduce:bsp:shard"
    plan = DistPlan.parse(s)
    assert plan.axes[1].role == "shard"
    assert plan.shard_axis is plan.axes[1]
    assert plan.shard_size == 2
    assert plan.data_axes == (plan.axes[0],)
    assert plan.describe() == s
    # role `data` is the default and stays silent in describe()
    assert DistPlan.parse(plan.describe()) == plan


def test_plan_zero_constructor_matches_parse():
    assert DistPlan.zero(4, 2) == DistPlan.parse(
        "workers=4:allreduce:bsp,shard=2:allreduce:bsp:shard")


def test_plan_shard_role_validation():
    with pytest.raises(ValueError, match="role"):
        AxisSpec("w", 2, role="fsdp")
    # a shard axis must ride the fused allreduce (its pmean + local
    # slice IS the reduce-scatter)
    with pytest.raises(ValueError, match="allreduce"):
        AxisSpec("shard", 2, collective="gossip", role="shard")
    with pytest.raises(ValueError, match="at most one shard"):
        DistPlan(axes=(AxisSpec("s1", 2, role="shard"),
                       AxisSpec("s2", 2, role="shard")))


def test_plan_parse_zero3_role_round_trip():
    s = "workers=2:allreduce:bsp,shard=2:allreduce:bsp:zero3"
    plan = DistPlan.parse(s)
    assert plan.axes[1].role == "zero3"
    assert plan.shard_axis is plan.axes[1]  # zero3 IS the shard-role axis
    assert plan.shard_size == 2
    assert plan.data_axes == (plan.axes[0],)
    assert plan.describe() == s
    assert DistPlan.parse(plan.describe()) == plan


def test_plan_zero3_constructor_matches_parse():
    assert DistPlan.zero3(2, 2) == DistPlan.parse(
        "workers=2:allreduce:bsp,shard=2:allreduce:bsp:zero3")


def test_plan_zero3_role_validation():
    # the zero3 params all-gather rides the fused allreduce too
    with pytest.raises(ValueError, match="allreduce") as e:
        AxisSpec("shard", 2, collective="ps", role="zero3")
    assert "'shard'" in str(e.value)
    # gather-per-use reads the lag ring in lockstep: zero3 requires bsp
    with pytest.raises(ValueError, match="bsp") as e:
        AxisSpec("shard", 2, collective="allreduce", sync="asp",
                 role="zero3")
    assert "'shard'" in str(e.value)
    # shard and zero3 both claim the single shard-role slot
    with pytest.raises(ValueError, match="at most one shard"):
        DistPlan(axes=(AxisSpec("s1", 2, role="shard"),
                       AxisSpec("s2", 2, role="zero3")))


def test_plan_parse_zero3_rejections_name_offending_segment():
    for spec, frag in [
            ("w=2:allreduce:bsp,s=2:gossip:bsp:zero3", "'s'"),
            ("w=2:allreduce:bsp,s=2:allreduce:ssp:zero3", "'s'"),
            ("s1=2:allreduce:bsp:zero3,s2=2:allreduce:bsp:zero3",
             "at most one shard")]:
        with pytest.raises(ValueError) as e:
            DistPlan.parse(spec)
        assert frag in str(e.value), (spec, str(e.value))


def test_plan_parse_replay_role_round_trip():
    s = "workers=2:allreduce:bsp,replay=2:allreduce:bsp:replay"
    plan = DistPlan.parse(s)
    assert plan.axes[1].role == "replay"
    assert plan.replay_axis is plan.axes[1]
    assert plan.replay_size == 2
    assert plan.shard_axis is None  # replay is NOT the shard-role slot
    # replay members replicate their data position's rollout: the
    # simulation grid collapses the axis to 1
    assert plan.sim_shape == (2, 1) and plan.sim_devices == 2
    assert plan.describe() == s
    assert DistPlan.parse(plan.describe()) == plan


def test_plan_replay_constructor_matches_parse():
    assert DistPlan.replay(2, 2) == DistPlan.parse(
        "workers=2:allreduce:bsp,replay=2:allreduce:bsp:replay")


def test_plan_replay_composes_with_zero3_in_grammar():
    """shard/zero3 and replay occupy orthogonal role slots: one plan may
    carry both (the fit-parity pin lives in tests/test_replay_service)."""
    plan = DistPlan.parse(
        "workers=2:allreduce:bsp,shard=2:allreduce:bsp:zero3,"
        "replay=2:allreduce:bsp:replay")
    assert plan.shard_axis.name == "shard"
    assert plan.replay_axis.name == "replay"
    assert plan.sim_shape == (2, 2, 1) and plan.sim_devices == 4


def test_plan_replay_role_validation():
    # the merge/assembly collectives ride the fused allreduce domain
    with pytest.raises(ValueError, match="allreduce") as e:
        AxisSpec("rp", 2, collective="gossip", role="replay")
    assert "'rp'" in str(e.value)
    # one logical buffer -> lockstep members only
    with pytest.raises(ValueError, match="bsp") as e:
        AxisSpec("rp", 2, collective="allreduce", sync="asp",
                 role="replay")
    assert "'rp'" in str(e.value)
    with pytest.raises(ValueError, match="at most one replay"):
        DistPlan(axes=(AxisSpec("r1", 2, role="replay"),
                       AxisSpec("r2", 2, role="replay")))


def test_plan_parse_replay_rejections_name_offending_axis():
    for spec, frag in [
            ("w=2:allreduce:bsp,r=2:ps:bsp:replay", "'r'"),
            ("w=2:allreduce:bsp,r=2:allreduce:ssp:replay", "'r'"),
            ("r1=2:allreduce:bsp:replay,r2=2:allreduce:bsp:replay",
             "at most one replay")]:
        with pytest.raises(ValueError) as e:
            DistPlan.parse(spec)
        assert frag in str(e.value), (spec, str(e.value))


def test_plan_parse_rejects_bad_segments_naming_them():
    for spec, frag in [
            ("", "empty plan"),
            ("   ", "empty plan"),
            ("workers:4", "workers:4"),
            ("workers=x", "'x' is not an integer"),
            ("workers=4:allreduce:bsp:shard:x", "too many"),
            ("w=2:allreduce:bsp:zero", "role"),
            ("w=2,x=1,", "''")]:
        with pytest.raises(ValueError) as e:
            DistPlan.parse(spec)
        assert frag in str(e.value), (spec, str(e.value))


def test_plan_parse_rejects_duplicate_axis_names():
    with pytest.raises(ValueError) as e:
        DistPlan.parse("w=2:allreduce,w=2:gossip")
    assert "'w'" in str(e.value) and "duplicate" in str(e.value)


# ----------------------------------------- hypothesis plan round-trips
_NAMES = ("a", "b", "hosts", "workers", "shard", "x1", "grp")


@given(data=st.data())
@settings(**SETTINGS)
def test_plan_parse_describe_round_trip_property(data):
    """parse(describe(plan)) == plan for random axis tuples including
    ALL role slots (shard/zero3 and replay may coexist) — the CLI
    grammar is a faithful serialization."""
    n_axes = data.draw(st.integers(1, 4), label="n_axes")
    names = data.draw(st.permutations(list(_NAMES)), label="names")
    max_delay = data.draw(st.integers(0, 6), label="max_delay")
    staleness = data.draw(st.integers(0, 6), label="staleness")
    shard_at = data.draw(st.one_of(st.none(),
                                   st.integers(0, n_axes - 1)),
                         label="shard_at")
    replay_at = data.draw(st.one_of(st.none(),
                                    st.integers(0, n_axes - 1)),
                          label="replay_at")
    if replay_at == shard_at:  # orthogonal slots, distinct axes
        replay_at = None
    axes = []
    for i in range(n_axes):
        if i == shard_at:
            coll = "allreduce"
            role = data.draw(st.sampled_from(("shard", "zero3")),
                             label="shard_role")
        elif i == replay_at:
            coll, role = "allreduce", "replay"
        else:
            coll = data.draw(
                st.sampled_from(("allreduce", "ps", "gossip")))
            role = "data"
        sync = ("bsp" if role in ("zero3", "replay")  # bsp-only roles
                else data.draw(st.sampled_from(("bsp", "asp", "ssp"))))
        axes.append(AxisSpec(
            names[i], data.draw(st.integers(1, 8)), coll, sync,
            max_delay, staleness, role))
    plan = DistPlan(axes=tuple(axes))
    s = plan.describe()
    again = DistPlan.parse(s, max_delay=max_delay,
                           staleness_bound=staleness)
    assert again == plan
    assert again.describe() == s


@given(data=st.data())
@settings(**SETTINGS)
def test_plan_parse_malformed_segment_named_property(data):
    """Malformed axis segments raise ValueError naming the segment."""
    bad = data.draw(st.sampled_from(
        ("nosize", "w=three", "w=2:allreduce:bsp:data:extra")))
    spec = "ok=2:allreduce:bsp," + bad
    with pytest.raises(ValueError) as e:
        DistPlan.parse(spec)
    assert bad in str(e.value)


# -------------------------------- flatten-and-pad + sharded optimizer
def test_shard_flatten_and_pad_round_trip():
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))}
    vec, size, unravel = flatten_and_pad(tree, 4)
    assert size == 9 and vec.shape == (12,)  # padded to multiple of 4
    np.testing.assert_array_equal(np.asarray(vec[9:]), 0.0)
    back = unravel(vec[:size])
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    with pytest.raises(ValueError, match="empty"):
        flatten_and_pad({}, 2)


def test_shard_reduce_scatter_allgather_round_trip_under_vmap():
    """local_shard / all_gather_shards invert each other on a
    replicated vector (the trainer's situation: every shard member
    holds the same params), and reduce_scatter_mean is pmean + local
    chunk — exercised through vmap named axes (the same collective
    primitives shard_map lowers)."""
    n = 4
    vec = jax.random.normal(jax.random.PRNGKey(0), (8,))
    rep = jnp.broadcast_to(vec, (n, 8))

    gathered = jax.vmap(
        lambda v: all_gather_shards(local_shard(v, "s", n), "s"),
        axis_name="s")(rep)
    np.testing.assert_array_equal(np.asarray(gathered), np.asarray(rep))

    vecs = jax.random.normal(jax.random.PRNGKey(1), (n, 8))
    rs = jax.vmap(lambda v: reduce_scatter_mean(v, "s", n),
                  axis_name="s")(vecs)
    mean = np.asarray(vecs).mean(axis=0)
    for i in range(n):
        np.testing.assert_allclose(np.asarray(rs[i]),
                                   mean[2 * i:2 * i + 2], rtol=1e-6)


def test_shard_zero_optimizer_matches_replicated():
    """The ZeRO wrapper (reduce-scattered grads -> 1/n-slice update ->
    all-gathered params) reproduces the replicated optimizer's params
    over several steps — including the global-norm-clip `pre` path —
    with opt_state living as 1/n chunks. Tolerance is one f32 ulp: the
    vmap'd chunk program and the plain tree program may FMA-contract
    differently (the end-to-end f32-bitwise pin, where both sides run
    under shard_map, lives in tests/test_trainer.py)."""
    n = 2
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    params = {"w": jax.random.normal(ks[0], (3, 3)),
              "b": jax.random.normal(ks[1], (2,))}  # 11 -> pad to 12
    opt = clip_by_global_norm(adamw(1e-2), 0.5)
    sh = zero_sharded_optimizer(opt, "s", n)

    stack = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.stack([a] * n), t)
    p_sh = stack(params)
    s_sh = stack(sh.init(params))     # all-zero chunks, like the Trainer
    p_rep, s_rep = params, opt.init(params)
    step = jax.jit(jax.vmap(sh.apply, axis_name="s"))
    for i in range(4):
        grads = {"w": 3 * jax.random.normal(ks[2], (3, 3)) * (i + 1),
                 "b": jax.random.normal(ks[3], (2,))}
        p_sh, s_sh = step(p_sh, s_sh, stack(grads))
        p_rep, s_rep = opt.apply(p_rep, s_rep, grads)
        for k in params:  # every shard member holds the full params
            for m in range(n):
                np.testing.assert_allclose(
                    np.asarray(p_sh[k][m]), np.asarray(p_rep[k]),
                    rtol=3e-7, atol=3e-7)
    # opt_state moments really are 1/n chunks (6 of padded 12 elements)
    assert s_sh["m"].shape == (n, 6) and s_sh["v"].shape == (n, 6)


def test_shard_size1_optimizer_is_inner_passthrough():
    """Sharding into one chunk is the identity: the wrapper delegates
    to the inner optimizer, keeping the tree-shaped opt_state (the
    size-1 bitwise no-op guarantee by construction)."""
    params = {"w": jnp.ones((2, 2))}
    opt = adamw(1e-3)
    sh = zero_sharded_optimizer(opt, "s", 1)
    st_ = sh.init(params)
    assert st_["m"]["w"].shape == (2, 2)  # tree form, not a chunk
    g = {"w": jnp.full((2, 2), 0.5)}
    p1, s1 = opt.apply(params, opt.init(params), g)
    p2, s2 = sh.apply(params, st_, g)
    for a, b in zip(jax.tree_util.tree_leaves((p1, s1)),
                    jax.tree_util.tree_leaves((p2, s2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shard_trainer_rejects_optless_agent():
    """A shard-role axis on an agent without `.opt` raises a clear
    error naming the algorithm and the axis (third-party agents must
    expose their optimizer to shard)."""
    import repro.core.agent as agent_api

    class NoOpt(agent_api.Agent):
        def __init__(self, env, **kw):
            pass

    agent_api.register("_no_opt", NoOpt)
    try:
        with pytest.raises(ValueError, match="_no_opt.*opt|opt.*_no_opt"):
            Trainer(CartPole(), TrainerConfig(
                algo="_no_opt", n_envs=8, plan=DistPlan.zero(1, 2)))
    finally:
        agent_api._REGISTRY.pop("_no_opt", None)


# -------------------------------------------------- CLI --plan contract
def _run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.rl_train", *args],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)


def test_cli_plan_rejects_empty():
    r = _run_cli("--plan", "")
    assert r.returncode != 0
    assert "empty --plan" in r.stderr


def test_cli_plan_rejects_duplicate_axis_names():
    r = _run_cli("--plan", "w=2:allreduce,w=2:gossip")
    assert r.returncode != 0
    assert "duplicate plan axis name 'w'" in r.stderr


def test_cli_plan_rejects_bad_role():
    r = _run_cli("--plan", "w=2:allreduce:bsp:fsdp")
    assert r.returncode != 0
    assert "role" in r.stderr


def test_cli_plan_shard_role_trains_and_reports_partition():
    """--plan with a shard-role segment forces the fake devices, trains
    through the ZeRO path and reports the partition (axis, shard count,
    flat/padded/chunk sizes) in the output JSON."""
    import json
    r = _run_cli("--plan", "workers=2:allreduce:bsp,"
                 "shard=2:allreduce:bsp:shard",
                 "--iters", "4", "--superstep", "2", "--n-envs", "8",
                 "--unroll", "4", "--log-every", "2")
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 4
    assert out["plan"].endswith("shard=2:allreduce:bsp:shard")
    part = out["partition"]
    assert part["axis"] == "shard" and part["n_shards"] == 2
    assert part["padded"] % 2 == 0
    assert part["chunk"] * 2 == part["padded"]
    assert out["history"]
