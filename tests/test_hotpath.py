"""Pallas-fused learner hot path (PR 3): single-forward rollouts pin
the legacy trajectories, zero-copy (donated) supersteps pin the
non-donated numerics, fused prioritized sampling trains DQN end-to-end,
and the benchmark JSON schema round-trips."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.networks import MLPPolicy
from repro.core.rollout import rollout
from repro.core.trainer import Trainer, TrainerConfig
from repro.envs import CartPole, Pendulum


# ------------------------------------------------- single-forward rollout
class _CountingPolicy:
    """MLPPolicy wrapper counting trunk evaluations at trace time."""

    def __init__(self, inner, fused):
        self._inner = inner
        self.discrete = inner.discrete
        self.calls = 0
        if fused:
            self.sample_value = self._sample_value

    def init(self, key):
        return self._inner.init(key)

    def apply(self, params, obs):
        self.calls += 1
        return self._inner.apply(params, obs)

    def sample(self, params, obs, key):
        pi, _ = self.apply(params, obs)
        return self._inner._dist_sample(params, pi, key)

    def _sample_value(self, params, obs, key):
        pi, v = self.apply(params, obs)
        a, logp = self._inner._dist_sample(params, pi, key)
        return a, logp, v


@pytest.mark.parametrize("env_cls", [CartPole, Pendulum])
def test_rollout_single_forward_identical_trajectories(env_cls, rng):
    """Regression for the double forward pass (sample + apply per env
    step): the fused sample_value path runs ONE trunk evaluation per
    step and produces BITWISE the same trajectory."""
    env = env_cls()
    pol = MLPPolicy.for_spec(env.spec, hidden=(16,))
    params = pol.init(rng)
    state = env.reset_batch(rng, 4)
    trajs, counts = {}, {}
    for fused in (False, True):
        cpol = _CountingPolicy(pol, fused)
        trajs[fused], _ = rollout(cpol, params, env, rng, state, 6)
        counts[fused] = cpol.calls
    # lax.scan traces the step body once: the trace-time call count IS
    # the per-step forward count
    assert counts[True] == 1 and counts[False] == 2, counts
    for k in trajs[False]:
        assert np.array_equal(np.asarray(trajs[False][k]),
                              np.asarray(trajs[True][k])), k


def test_qpolicy_sample_value_matches_sample_apply_pair(rng):
    """DQN's adapter: one q evaluation reproduces the 3-evaluation
    sample/apply pair bitwise (same ε-greedy key discipline)."""
    from repro.core.agent import make
    env = CartPole()
    ag = make("dqn", env=env, hidden=(16,))
    state = ag.init(rng)
    actor = ag.actor_policy(state, 0)
    obs = jax.random.normal(rng, (8, env.spec.obs_dim))
    a1, lp1 = ag.policy.sample(actor, obs, rng)
    _, v1 = ag.policy.apply(actor, obs)
    a2, lp2, v2 = ag.policy.sample_value(actor, obs, rng)
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(lp1), np.asarray(lp2))
    assert np.array_equal(np.asarray(v1), np.asarray(v2))


def test_rollout_fallback_for_policies_without_sample_value(rng):
    """Policies exposing only sample/apply still roll out (two-forward
    fallback)."""
    env = CartPole()
    pol = MLPPolicy.for_spec(env.spec, hidden=(16,))
    cpol = _CountingPolicy(pol, fused=False)
    assert not hasattr(cpol, "sample_value")
    traj, _ = rollout(cpol, pol.init(rng), env, rng, env.reset_batch(
        rng, 2), 3)
    assert traj["obs"].shape[:2] == (3, 2)


# --------------------------------------------------- zero-copy supersteps
@pytest.mark.parametrize("algo", ["dqn", "impala"])
def test_donated_superstep_numerically_unchanged(algo):
    """cfg.donate only changes buffer ownership, never numerics: full
    fit histories agree bitwise-ish across donate on/off."""
    env = CartPole()

    def run(donate):
        cfg = TrainerConfig(algo=algo, iters=6, superstep=3, n_envs=8,
                            unroll=6, log_every=2, seed=5, donate=donate,
                            algo_kwargs=(
                                {"hidden": (16,), "replay_capacity": 512,
                                 "warmup": 1} if algo == "dqn"
                                else {"hidden": (16,)}))
        return Trainer(env, cfg).fit()

    s1, h1 = run(True)
    s2, h2 = run(False)
    assert len(h1) == len(h2)
    for r1, r2 in zip(h1, h2):
        assert r1.keys() == r2.keys()
        for k in r1:  # array_equal: NaN (pre-first-episode) == NaN
            np.testing.assert_array_equal(np.float64(r1[k]),
                                          np.float64(r2[k]), err_msg=k)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)


def test_donated_superstep_aliases_buffers():
    """The donated program actually aliases its carried state: XLA's
    memory analysis reports a nonzero donated-alias footprint covering
    at least the replay store."""
    env = CartPole()
    base = dict(algo="dqn", iters=4, superstep=2, n_envs=4, unroll=4,
                algo_kwargs={"replay_capacity": 1024, "hidden": (8,)})
    tr_on = Trainer(env, TrainerConfig(donate=True, **base))
    tr_off = Trainer(env, TrainerConfig(donate=False, **base))
    ma_on = tr_on.lower(2).compile().memory_analysis()
    ma_off = tr_off.lower(2).compile().memory_analysis()
    assert ma_off.alias_size_in_bytes == 0
    replay_store_bytes = 1024 * (4 * 4 * 2 + 4 + 4 + 1)
    assert ma_on.alias_size_in_bytes >= replay_store_bytes


# ------------------------------------------------ fused sampling training
def test_dqn_trains_with_fused_sampling():
    """DQN with the Gumbel-top-k sampler (kernel on TPU, ref oracle
    here) trains end-to-end through the unchanged Trainer."""
    from repro.envs import GridWorld
    env = GridWorld(n=4, max_steps=16)
    cfg = TrainerConfig(algo="dqn", iters=30, superstep=10, n_envs=16,
                        unroll=8, log_every=10,
                        algo_kwargs={"warmup": 3, "eps_decay_steps": 20,
                                     "target_update": 10,
                                     "fused_sampling": True,
                                     "replay_capacity": 4096})
    _, hist = Trainer(env, cfg).fit()
    assert all(np.isfinite(r["loss"]) for r in hist)
    final = hist[-1]["episode_return"]
    assert np.isfinite(final) and final >= 0.8 * hist[0][
        "episode_return"], hist


# ------------------------------------------------------- bench JSON schema
def test_write_bench_json_schema(tmp_path, monkeypatch):
    from benchmarks import common
    monkeypatch.setattr(common, "REPO_ROOT", str(tmp_path))
    rows = [("x/y", 12.345, "k=1"), ("x/z", None, "x2.0")]
    path = common.write_bench_json("unittest", rows, quick=True)
    doc = json.loads(open(path).read())
    assert doc["schema"] == "repro-bench/v1"
    assert doc["benchmark"] == "unittest"
    assert doc["meta"] == {"quick": True}
    assert doc["rows"][0] == {"name": "x/y", "us_per_call": 12.35,
                              "derived": "k=1"}
    assert doc["rows"][1]["us_per_call"] is None
    assert os.path.basename(path) == "BENCH_unittest.json"
    # out_dir redirects away from REPO_ROOT (how CLI tests avoid
    # clobbering the committed full-run files)
    sub = tmp_path / "elsewhere"
    sub.mkdir()
    path2 = common.write_bench_json("unittest", rows, out_dir=str(sub),
                                    quick=True)
    assert os.path.dirname(path2) == str(sub)
    assert json.loads(open(path2).read()) == doc
