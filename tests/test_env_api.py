"""Env-substrate conformance suite (Environment API v2).

Every REGISTERED env (base, scenario family, wrapped variant) and every
wrapper combo must satisfy the same contract: spec/obs agreement,
jit+vmap-able reset/step, autoreset surfacing the pre-reset terminal
observation, scenario batching, and one fused Trainer superstep with no
Trainer changes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.envs as envs
from repro.envs import (ActionRepeat, CartPole, EnvSpec, GridWorld,
                        ObsNormalize, Pendulum, RewardScale, TimeLimit,
                        box)

B = 8

WRAPPERS = {
    "timelimit": lambda e: TimeLimit(e, 5),
    "obsnorm": lambda e: ObsNormalize(e),
    "rewscale": lambda e: RewardScale(e, 0.5),
    "repeat": lambda e: ActionRepeat(e, 2),
    "stack": lambda e: ObsNormalize(TimeLimit(RewardScale(e, 0.5), 5)),
}
BASES = {"cartpole": CartPole, "pendulum": Pendulum,
         "gridworld": GridWorld}


def _batch_actions(env, key, n):
    return jax.vmap(env.spec.action.sample)(jax.random.split(key, n))


def _conformance(env, key):
    """The shared contract checked for every env × wrapper combo."""
    spec = env.spec
    assert isinstance(spec, EnvSpec)
    assert spec.action.discrete == (spec.n_actions > 0)

    # reset/obs under jit+vmap, shapes/dtypes agree with the spec
    state = jax.jit(lambda k: env.reset_batch(k, B))(key)
    obs = jax.jit(jax.vmap(env.obs))(state)
    assert obs.shape == (B,) + spec.observation.shape
    assert obs.dtype == spec.observation.dtype
    assert np.all(np.isfinite(obs))

    # step under jit+vmap
    a = _batch_actions(env, key, B)
    s2, o2, r, d = jax.jit(jax.vmap(env.step))(state, a)
    assert o2.shape == obs.shape and o2.dtype == spec.observation.dtype
    assert r.shape == (B,) and d.shape == (B,) and d.dtype == jnp.bool_
    assert np.all(np.isfinite(o2)) and np.all(np.isfinite(r))

    # autoreset invariant: the returned obs is the PRE-reset obs that
    # `step` emitted — bit-identical to a plain step_batch — never the
    # fresh-reset obs
    s3, o3, r3, d3 = jax.jit(env.step_autoreset)(state, a, key)
    np.testing.assert_array_equal(o3, o2)
    np.testing.assert_array_equal(r3, r)
    np.testing.assert_array_equal(d3, d)
    # and the merged state is live: another step works
    a2 = _batch_actions(env, jax.random.fold_in(key, 1), B)
    env.step_autoreset(s3, a2, jax.random.fold_in(key, 2))


@pytest.mark.parametrize("name", envs.available())
def test_registered_env_conformance(name, rng):
    _conformance(envs.make(name), rng)


@pytest.mark.parametrize("wrapper", sorted(WRAPPERS))
@pytest.mark.parametrize("base", sorted(BASES))
def test_wrapped_env_conformance(base, wrapper, rng):
    _conformance(WRAPPERS[wrapper](BASES[base]()), rng)


# --------------------------------------------------------------- registry
def test_registry_contains_seed_scenario_and_wrapped_envs():
    names = set(envs.available())
    assert {"cartpole", "pendulum", "gridworld"} <= names
    assert {"cartpole-rand", "pendulum-rand", "gridworld-rand"} <= names
    assert {"pendulum-norm", "cartpole-repeat"} <= names


def test_make_unknown_env_raises():
    with pytest.raises(KeyError, match="unknown environment"):
        envs.make("nope")


def test_make_forwards_kwargs(rng):
    env = envs.make("gridworld", n=4, max_steps=7)
    assert env.spec.episode_len == 7
    state = env.reset(rng)
    assert int(state["scn"]["n"]) == 4


# ------------------------------------------------- autoreset boundary fix
def test_autoreset_surfaces_terminal_obs_pinned(rng):
    """Regression (seed bug): step_autoreset discarded the terminal
    observation. With a 1-step TimeLimit every step is a boundary: the
    returned obs must be the physics successor of the PRE-reset state,
    and the merged state must already be a fresh episode."""
    env = TimeLimit(CartPole(), 1)
    state = env.reset_batch(rng, B)
    a = _batch_actions(env, rng, B)
    _, terminal_obs, _, done = jax.vmap(env.step)(state, a)
    new_state, obs, _, d = env.step_autoreset(state, a, rng)
    assert bool(jnp.all(d))                      # every env hit the limit
    np.testing.assert_array_equal(obs, terminal_obs)
    # the state actually reset: fresh counters, and the obs of the new
    # episode differs from the terminal one
    np.testing.assert_array_equal(np.asarray(new_state["wrap"]["t"]),
                                  np.zeros(B, np.int32))
    fresh_obs = jax.vmap(env.obs)(new_state)
    assert not np.allclose(fresh_obs, obs)


def test_rollout_next_obs_is_true_successor(rng):
    """Through the rollout engine: next_obs[t] == obs[t+1] at non-done
    steps, and at done steps it is the terminal obs of the OLD episode
    (not the fresh-reset obs recorded at t+1)."""
    from repro.core.networks import MLPPolicy
    from repro.core.rollout import rollout
    env = TimeLimit(CartPole(), 3)
    pol = MLPPolicy.for_spec(env.spec, hidden=(8,))
    params = pol.init(rng)
    state = env.reset_batch(rng, 4)
    traj, _ = rollout(pol, params, env, rng, state, 9)
    nxt, obs, done = (np.asarray(traj[k])
                      for k in ("next_obs", "obs", "done"))
    cont = ~done[:-1]
    np.testing.assert_allclose(nxt[:-1][cont], obs[1:][cont], rtol=1e-6)
    assert done.any()
    # boundary rows: successor recorded pre-reset, so it differs from
    # the fresh obs the next row starts from
    b_nxt, b_fresh = nxt[:-1][done[:-1]], obs[1:][done[:-1]]
    assert not np.allclose(b_nxt, b_fresh)


def test_obsnorm_stats_survive_autoreset(rng):
    """ObsNormalize's running statistics must NOT reset at episode
    boundaries (wrap_merge keeps the stepped state)."""
    env = ObsNormalize(TimeLimit(Pendulum(), 2))
    state = env.reset_batch(rng, 4)
    for i in range(6):
        a = _batch_actions(env, jax.random.fold_in(rng, i), 4)
        state, _, _, d = env.step_autoreset(state, a,
                                            jax.random.fold_in(rng, i))
    # 6 steps (with boundaries every 2) on top of the init count of 1
    np.testing.assert_array_equal(np.asarray(state["wrap"]["count"]),
                                  np.full(4, 7.0, np.float32))
    # ...while the TimeLimit counter below it did reset
    assert int(jnp.max(state["inner"]["wrap"]["t"])) <= 2


# --------------------------------------------------------- scenario API
@pytest.mark.parametrize("name,field", [("cartpole-rand", "masspole"),
                                        ("pendulum-rand", "m"),
                                        ("gridworld-rand", "n")])
def test_scenario_batch_is_diverse(name, field, rng):
    """One reset_batch draws a DISTRIBUTION of scenario variants."""
    env = envs.make(name)
    state = env.reset_batch(rng, 16)
    values = np.asarray(state["scn"][field])
    assert values.shape[0] == 16
    assert len(np.unique(values)) > 1


def test_gridworld_rand_goal_inside_grid(rng):
    env = envs.make("gridworld-rand")
    state = env.reset_batch(rng, 32)
    n = np.asarray(state["scn"]["n"])
    goal = np.asarray(state["scn"]["goal"])
    assert (goal >= 0).all() and (goal < n[:, None]).all()
    assert (n >= 4).all() and (n <= 8).all()


def test_gridworld_size_range_keeps_default_goal_reachable(rng):
    """Randomizing only the grid size must clamp the (n-1, n-1) default
    goal into the sampled grid instead of leaving it unreachable."""
    env = GridWorld(n=8, ranges={"n": (4, 6)})
    state = env.reset_batch(rng, 32)
    n = np.asarray(state["scn"]["n"])
    goal = np.asarray(state["scn"]["goal"])
    assert (n <= 6).all()
    assert (goal < n[:, None]).all()


def test_obsnorm_spec_publishes_normalized_bounds():
    """ObsNormalize rescales observations, so it must publish its own
    clip bounds instead of inheriting the inner env's."""
    env = envs.make("pendulum-norm")
    obs_space = env.spec.observation
    assert obs_space.low == -10.0 and obs_space.high == 10.0
    assert Pendulum().spec.observation.high == 1.0  # inner untouched


def test_scenario_override_and_validation(rng):
    env = CartPole(scenario={"masspole": 0.3})
    state = env.reset(rng)
    assert float(state["scn"]["masspole"]) == pytest.approx(0.3)
    with pytest.raises(KeyError, match="unknown scenario field"):
        CartPole(scenario={"bogus": 1.0})
    with pytest.raises(KeyError, match="unknown scenario range"):
        Pendulum(ranges={"bogus": (0.0, 1.0)})


def test_scenario_dynamics_actually_differ(rng):
    """Same state+action under two scenarios -> different physics."""
    heavy = CartPole(scenario={"masspole": 1.0}).reset(rng)
    light = CartPole(scenario={"masspole": 0.01}).reset(rng)
    light["s"] = heavy["s"]  # identical kinematic state
    _, o_heavy, _, _ = CartPole().step(heavy, jnp.int32(1))
    _, o_light, _, _ = CartPole().step(light, jnp.int32(1))
    assert not np.allclose(o_heavy, o_light)


# ------------------------------------------- spec-driven action scaling
def test_episode_return_reads_action_bounds_from_spec(rng):
    """Regression (seed bug): episode_return hard-coded Pendulum's
    max_torque (tanh * 2.0). A saturated policy on a ±0.5 box must
    produce actions at +0.5, so 4 steps of reward == action sum to 2.0
    (the old code would have produced 8.0)."""
    from repro.core.networks import MLPPolicy
    from repro.core.rollout import episode_return

    class _BoundsProbe:
        spec = EnvSpec("probe", observation=box((1,)),
                       action=box((1,), low=-0.5, high=0.5),
                       episode_len=4)

        def reset(self, key):
            return {"t": jnp.zeros((), jnp.int32)}

        def obs(self, state):
            return jnp.zeros((1,))

        def step(self, state, action):
            t = state["t"] + 1
            return ({"t": t}, jnp.zeros((1,)), action.reshape(())[None][0],
                    t >= 4)

    env = _BoundsProbe()
    pol = MLPPolicy.for_spec(env.spec, hidden=(4,))
    params = pol.init(rng)
    params["pi"]["b"] = jnp.full_like(params["pi"]["b"], 10.0)  # saturate
    total = float(episode_return(pol, params, env, rng, max_steps=4))
    assert total == pytest.approx(4 * 0.5, abs=1e-2)


def test_for_spec_policy_respects_pendulum_bounds(rng):
    from repro.core.networks import MLPPolicy
    env = Pendulum()
    pol = MLPPolicy.for_spec(env.spec, hidden=(8,))
    assert pol.act_scale == pytest.approx(env.max_torque)
    a, logp = pol.sample(pol.init(rng), jnp.zeros((16, 3)), rng)
    assert np.all(np.abs(np.asarray(a)) <= env.max_torque + 1e-5)
    assert np.all(np.isfinite(np.asarray(logp)))


# ------------------------------------- Trainer integration (acceptance)
@pytest.mark.parametrize("name", envs.available())
def test_trainer_one_superstep_every_registered_env(name):
    """Acceptance: every registered env — scenario families and wrapped
    variants included — trains one fused superstep under the existing
    Trainer with zero Trainer changes."""
    from repro.core.trainer import Trainer, TrainerConfig
    cfg = TrainerConfig(algo="impala", iters=2, superstep=2, n_envs=4,
                        unroll=4, log_every=1, seed=0,
                        algo_kwargs={"hidden": (8,)})
    _, hist = Trainer(envs.make(name), cfg).fit()
    assert len(hist) == 2
    assert np.isfinite(hist[-1]["loss"])


def test_wrapped_rollout_stays_zero_copy(rng):
    """The wrapper stack must not break the single-XLA-program property
    (no host callbacks in the jaxpr)."""
    from repro.core.networks import MLPPolicy
    from repro.core.rollout import rollout
    env = ObsNormalize(TimeLimit(RewardScale(CartPole(), 0.5), 6))
    pol = MLPPolicy.for_spec(env.spec, hidden=(8,))
    params = pol.init(rng)
    state = env.reset_batch(rng, 4)
    jaxpr = jax.make_jaxpr(
        lambda p, k, s: rollout(pol, p, env, k, s, 8))(params, rng, state)
    assert "callback" not in str(jaxpr), "env must not round-trip host"
