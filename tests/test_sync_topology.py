"""Synchronization mechanisms + topologies (survey §3/§6).

Single-device tests run in-process; multi-device topology tests spawn a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
main process must keep seeing exactly one device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sync import (SyncConfig, make_delays, train_with_staleness,
                             sync_cost_model)
from repro.optim import sgd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _quad_problem(key, T=30, W=4):
    x = jax.random.normal(key, (T, W, 16, 3))
    w_true = jnp.array([1.0, -2.0, 0.5])
    y = jnp.einsum("twbd,d->twb", x, w_true)
    loss = lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
    return loss, {"x": x, "y": y}, {"w": jnp.zeros((3,))}


def test_bsp_equals_plain_sgd(rng):
    """BSP with delay 0 must be bit-identical to synchronous SGD over the
    combined batch."""
    loss, batches, p0 = _quad_problem(rng)
    d = make_delays(SyncConfig("bsp", 4), 30, rng)
    p_bsp, losses = train_with_staleness(loss, p0, sgd(0.1), batches, d)
    # plain SGD over the worker-mean gradient
    opt = sgd(0.1)
    st_ = opt.init(p0)
    p = p0
    for t in range(30):
        b = jax.tree_util.tree_map(lambda a: a[t], batches)
        g = jax.vmap(jax.grad(loss))(p and jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (4,) + x.shape), p), b)
        g = jax.tree_util.tree_map(lambda a: a.mean(0), g)
        p, st_ = opt.apply(p, st_, g)
    np.testing.assert_allclose(p_bsp["w"], p["w"], atol=1e-6)


@pytest.mark.parametrize("seed", range(0, 50, 5))
def test_ssp_delays_bounded(seed):
    cfg = SyncConfig("ssp", 8, max_delay=10, staleness_bound=2)
    d = make_delays(cfg, 50, jax.random.PRNGKey(seed))
    assert int(d.max()) <= 2


def test_staleness_ordering(rng):
    """Survey Fig. 6 claim: convergence quality BSP >= SSP >= ASP for
    aggressive learning rates."""
    loss, batches, p0 = _quad_problem(rng, T=60)
    final = {}
    for mech in ("bsp", "ssp", "asp"):
        cfg = SyncConfig(mech, 4, max_delay=8, staleness_bound=1)
        d = make_delays(cfg, 60, jax.random.PRNGKey(7))
        _, losses = train_with_staleness(loss, p0, sgd(0.35), batches, d)
        final[mech] = float(jnp.mean(losses[-10:]))
    assert final["bsp"] <= final["ssp"] * 1.5 + 1e-6
    assert final["ssp"] <= final["asp"] + 1e-6, final


def test_sync_cost_model_ordering(rng):
    """Throughput: ASP <= SSP <= BSP wall-time under heterogeneity."""
    times = {}
    for mech in ("bsp", "ssp", "asp"):
        cfg = SyncConfig(mech, 16, staleness_bound=4)
        times[mech] = float(sync_cost_model(cfg, 1.0, 0.3, 100, rng))
    assert times["asp"] <= times["ssp"] <= times["bsp"], times


@pytest.mark.parametrize("std", (0.01, 0.05, 0.2, 0.5))
@pytest.mark.parametrize("seed", (0, 1, 2))
def test_sync_cost_model_ordering_positive_variance(std, seed):
    """Sanity pin (survey §6.2): for ANY positive straggler variance the
    predicted per-iteration cost must order bsp >= ssp >= asp — the
    barrier hierarchy is monotone in how often workers wait, regardless
    of how heterogeneous they are."""
    times = {}
    for mech in ("bsp", "ssp", "asp"):
        cfg = SyncConfig(mech, 16, max_delay=8, staleness_bound=4)
        times[mech] = float(sync_cost_model(cfg, 1.0, std, 96,
                                            jax.random.PRNGKey(seed)))
    assert times["asp"] <= times["ssp"] <= times["bsp"], \
        (std, seed, times)


# ------------------------------------------------- multi-device topology
_TOPOLOGY_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import Mesh
    from repro.core.topology import make_distributed_step, replicate_for
    from repro.optim import sgd
    mesh = Mesh(np.array(jax.devices()).reshape(8,), ("workers",))
    def loss(p, b): return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 32, 3))
    y = jnp.einsum("wbd,d->wb", x, jnp.array([1.0, -2.0, 0.5]))
    p0 = {"w": jnp.zeros((3,))}
    opt = sgd(0.3)
    out = {}
    for topo in ("allreduce", "ps", "gossip"):
        params = replicate_for(mesh, "workers", p0)
        ostate = replicate_for(mesh, "workers", opt.init(p0))
        step = make_distributed_step(loss, opt, topo, mesh)
        spread0 = None
        for i in range(25):
            params, ostate, l = step(params, ostate, {"x": x, "y": y})
            if i == 3:
                spread0 = float(jnp.max(jnp.std(params["w"], axis=0)))
        out[topo] = {"loss": float(l),
                     "spread_early": spread0,
                     "spread_final": float(jnp.max(jnp.std(
                         params["w"], axis=0)))}
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def topology_results():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _TOPOLOGY_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_all_topologies_converge(topology_results):
    for topo, res in topology_results.items():
        assert res["loss"] < 1e-3, (topo, res)


def test_sync_topologies_keep_replicas_identical(topology_results):
    for topo in ("allreduce", "ps"):
        assert topology_results[topo]["spread_early"] < 1e-6


def test_gossip_replicas_eps_close_not_identical(topology_results):
    """Gossip keeps models ε-close (survey §3.3, Assran et al.) — they
    drift (different local grads) but the mixing bounds the spread."""
    g = topology_results["gossip"]
    assert g["spread_early"] > 1e-6, "gossip replicas should differ early"
    assert g["spread_final"] < 0.05, "gossip spread must stay bounded"
