"""Transformer trunk policy (PR 8): TrunkPolicy.for_spec / make_policy
units, all four algorithms training the trunk through the unchanged
Trainer, and trunk x ZeRO-3 parity. The trunk's attention runs through
core/attention.py (flash-attention dispatcher); off-TPU the kernel path
falls back to the ref bitwise, so everything here is backend-portable.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.envs as envs
from repro.configs.base import ATTN, ModelConfig
from repro.core.networks import MLPPolicy, TrunkPolicy, make_policy

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

TINY = ModelConfig(name="tiny-trunk", family="dense", n_layers=2,
                   d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                   vocab=64, layer_pattern=(ATTN,))


# ----------------------------------------------------------- unit level
def test_trunk_for_spec_feature_mode_discrete():
    """Float observations lift per-feature into d_model (no token
    embedding); discrete head samples valid actions."""
    env = envs.make("cartpole")
    pol = TrunkPolicy.for_spec(env.spec, arch=TINY, reduced=False)
    assert pol.features == 4 and pol.n_actions == 2
    params = pol.init(jax.random.PRNGKey(0))
    assert "feat" in params and params["feat"]["w"].shape == (4, 32)
    obs = jax.random.normal(jax.random.PRNGKey(1), (6, 4))
    logits, v = pol.apply(params, obs)
    assert logits.shape == (6, 2) and v.shape == (6,)
    a, logp = pol.sample(params, obs, jax.random.PRNGKey(2))
    assert a.shape == (6,) and a.dtype == jnp.int32
    assert bool(jnp.all((a >= 0) & (a < 2)))
    assert bool(jnp.all(jnp.isfinite(logp)))


def test_trunk_for_spec_continuous_head():
    """Continuous action spaces get a tanh-squashed Gaussian head with a
    learned log_std, same contract as MLPPolicy."""
    env = envs.make("pendulum")
    pol = TrunkPolicy.for_spec(env.spec, arch=TINY, reduced=False)
    params = pol.init(jax.random.PRNGKey(0))
    assert "log_std" in params and params["log_std"].shape == (1,)
    obs = jax.random.normal(jax.random.PRNGKey(1), (5, 3))
    a, logp = pol.sample(params, obs, jax.random.PRNGKey(2))
    assert a.shape == (5, 1)
    assert bool(jnp.all(jnp.abs(a) <= 2.0 + 1e-6))
    assert bool(jnp.all(jnp.isfinite(logp)))


def test_trunk_token_mode_keeps_embedding_path():
    """Integer observations (token histories) embed through the LM's
    vocab table — the PR 4 contract test_system pins stays intact."""
    pol = TrunkPolicy(TINY, n_actions=4, ctx=4)
    assert pol.features is None
    params = pol.init(jax.random.PRNGKey(0))
    assert "feat" not in params
    obs = jax.random.randint(jax.random.PRNGKey(1), (3, 4), 0, 64)
    logits, v = pol.apply(params, obs)
    assert logits.shape == (3, 4) and v.shape == (3,)


def test_trunk_make_policy_factory():
    env = envs.make("cartpole")
    assert isinstance(make_policy(env.spec, "mlp"), MLPPolicy)
    pol = make_policy(env.spec, "trunk", arch=TINY, reduced=False)
    assert isinstance(pol, TrunkPolicy)
    with pytest.raises(ValueError, match="policy"):
        make_policy(env.spec, "resnet")


def test_trunk_kernel_dispatch_matches_jnp_attention():
    """use_kernels=True routes attention through the core dispatcher
    (off-TPU: the flash ref); use_kernels=False keeps the model's
    chunked jnp path. Same math, different summation order — the two
    applies must agree to float32 tolerance."""
    env = envs.make("cartpole")
    p_ref = TrunkPolicy.for_spec(env.spec, arch=TINY, reduced=False,
                                 use_kernels=False)
    p_ker = TrunkPolicy.for_spec(env.spec, arch=TINY, reduced=False,
                                 use_kernels=True)
    params = p_ref.init(jax.random.PRNGKey(0))
    obs = jax.random.normal(jax.random.PRNGKey(1), (4, 4))
    lo_r, v_r = p_ref.apply(params, obs)
    lo_k, v_k = p_ker.apply(params, obs)
    np.testing.assert_allclose(np.asarray(lo_r), np.asarray(lo_k),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(v_r), np.asarray(v_k),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------- Trainer end-to-end (1 dev)
_ALGO_KW = {"a3c": {}, "impala": {}, "ppo": {},
            "dqn": {"replay_capacity": 256, "warmup": 0}}


@pytest.mark.parametrize("algo", sorted(_ALGO_KW))
def test_trunk_trains_through_trainer(algo):
    """All four algorithms fit the transformer trunk through the
    unchanged Trainer — --policy trunk is one kwarg, not a fork."""
    from repro.core.trainer import Trainer, TrainerConfig
    env = envs.make("cartpole")
    kw = dict(_ALGO_KW[algo], policy="trunk",
              trunk_kwargs={"arch": TINY, "reduced": False})
    cfg = TrainerConfig(algo=algo, iters=2, superstep=2, n_envs=4,
                        unroll=4, log_every=1, seed=0, algo_kwargs=kw)
    state, hist = Trainer(env, cfg).fit()
    assert len(hist) == 2
    assert all(np.isfinite(r["loss"]) for r in hist), (algo, hist)
    assert "feat" in (state.params if "online" not in state.params
                      else state.params["online"])


# ----------------------------------- trunk x ZeRO-3 (8 fake devices)
_TRUNK_ZERO3_SCRIPT = textwrap.dedent("""
    import json
    import jax, numpy as np
    import repro.envs as envs
    from repro.configs.base import ATTN, ModelConfig
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig

    TINY = ModelConfig(name="tiny-trunk", family="dense", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                       vocab=64, layer_pattern=(ATTN,))
    env = envs.make("cartpole")

    def fit(plan):
        kw = {"policy": "trunk",
              "trunk_kwargs": {"arch": TINY, "reduced": False}}
        cfg = TrainerConfig(algo="impala", iters=4, superstep=2,
                            n_envs=8, unroll=6, plan=plan, log_every=1,
                            seed=0, algo_kwargs=kw)
        return Trainer(env, cfg).fit()

    s_flat, h_flat = fit(DistPlan.flat(4))
    s_z3, h_z3 = fit(DistPlan.zero3(2, 2))
    l_f = jax.tree_util.tree_leaves(s_flat.params)
    l_z = jax.tree_util.tree_leaves(s_z3.params)
    diffs = [float(np.abs(np.asarray(a, np.float64)
                          - np.asarray(b, np.float64)).max())
             for a, b in zip(l_f, l_z)]
    scale = max(float(np.abs(np.asarray(a)).max()) for a in l_f)
    out = {"n_leaves_match": len(l_f) == len(l_z),
           "max_abs_diff": max(diffs), "param_scale": scale,
           "losses_finite": all(np.isfinite(r["loss"]) for r in h_z3),
           "n_hist": len(h_z3)}
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_trunk_zero3_fit_matches_replicated():
    """The trunk under a zero3-role axis trains to the same params as
    the flat replicated plan (tight allclose: the gathered-params
    prologue changes XLA fusion, so a few ulps of drift accumulate over
    steps — same behavior as the shipped ZeRO-2 axis on this policy;
    the MLP fits are pinned f32-bitwise in test_trainer.py)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _TRUNK_ZERO3_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["n_leaves_match"] and out["losses_finite"]
    assert out["n_hist"] == 4
    assert out["max_abs_diff"] <= 1e-5 * max(out["param_scale"], 1.0), out
