"""Model-component correctness: attention variants vs naive oracle, MoE
sort-dispatch vs dense oracle, mamba chunked scan vs per-step scan."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import (causal_attention, local_attention,
                                    flash_block_attention)


def _qkv(rng, B, S, KVH, G, D):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, KVH, G, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    return q, k, v


def _to_ref(qg):
    B, S, KVH, G, D = qg.shape
    return qg.transpose(0, 2, 3, 1, 4).reshape(B, KVH * G, S, D)


@pytest.mark.parametrize("S,nq,bk", [(64, 4, 16), (100, 8, 32),
                                     (256, 2, 128)])
def test_causal_attention_matches_naive(S, nq, bk, rng):
    B, KVH, G, D = 2, 2, 2, 16
    q, k, v = _qkv(rng, B, S, KVH, G, D)
    o = causal_attention(q, k, v, jnp.int32(0), n_q_chunks=nq, block_k=bk)
    r = attention_ref(_to_ref(q), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=True)
    r = r.reshape(B, KVH, G, S, D).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(o, r, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,w", [(64, 16), (128, 32), (96, 32)])
def test_local_attention_matches_naive(S, w, rng):
    B, KVH, G, D = 1, 1, 4, 16
    q, k, v = _qkv(rng, B, S, KVH, G, D)
    o = local_attention(q, k, v, jnp.int32(0), window=w)
    r = attention_ref(_to_ref(q), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=True, window=w)
    r = r.reshape(B, KVH, G, S, D).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(o, r, atol=2e-5, rtol=2e-5)


def test_flash_block_attention_valid_len(rng):
    """kv_valid_len masks trailing cache slots."""
    B, S, KVH, G, D = 1, 8, 1, 1, 16
    q, k, v = _qkv(rng, B, S, KVH, G, D)
    o_full = flash_block_attention(q, k[:, :6], v[:, :6],
                                   jnp.arange(S), 0, causal=False,
                                   window=0, block_k=8)
    o_mask = flash_block_attention(q, k, v, jnp.arange(S), 0,
                                   causal=False, window=0, block_k=8,
                                   kv_valid_len=6)
    np.testing.assert_allclose(o_full, o_mask, atol=1e-5)


# ----------------------------------------------------------------- MoE
def test_moe_sort_dispatch_matches_dense_oracle(rng):
    from repro.models.moe import init_moe, apply_moe, \
        apply_moe_dense_oracle
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    p = init_moe(cfg, rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 24, cfg.d_model))
    out, aux = apply_moe(cfg, p, x)
    ref = apply_moe_dense_oracle(cfg, p, x)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(rng):
    """With capacity_factor << 1 the dispatch must drop tokens (outputs
    differ from the dense oracle) but stay finite."""
    from repro.models.moe import init_moe, apply_moe, \
        apply_moe_dense_oracle
    cfg = get_config("llama4-maverick-400b-a17b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.3))
    p = init_moe(cfg, rng)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, cfg.d_model))
    out, _ = apply_moe(cfg, p, x)
    ref = apply_moe_dense_oracle(cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert not bool(jnp.allclose(out, ref, atol=1e-5))


# ---------------------------------------------------------------- mamba
def test_mamba_chunked_matches_step_scan(rng):
    from repro.models.mamba import ssm_scan_chunked
    B, T, di, N = 2, 40, 8, 4
    ks = jax.random.split(rng, 4)
    u = jax.random.normal(ks[0], (B, T, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, di)))
    Bm = jax.random.normal(ks[2], (B, T, N))
    Cm = jax.random.normal(ks[3], (B, T, N))
    A = -jnp.exp(jax.random.normal(rng, (di, N)) * 0.3)
    h0 = jnp.zeros((B, di, N))
    y_c, s_c = ssm_scan_chunked(u, dt, Bm, Cm, A, h0, chunk=8)
    # per-step oracle
    def step(h, xs):
        ut, dtt, bt, ct = xs
        a = jnp.exp(dtt[:, :, None] * A)
        h = a * h + (dtt * ut)[:, :, None] * bt[:, None, :]
        return h, jnp.einsum("bdn,bn->bd", h, ct)
    xs = (u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    s_r, y_r = jax.lax.scan(step, h0, xs)
    np.testing.assert_allclose(y_c, y_r.transpose(1, 0, 2), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(s_c, s_r, atol=2e-4, rtol=1e-3)


def test_rwkv_decode_chain_matches_seq(rng):
    """Token-by-token chunk=1 decode equals one chunked pass."""
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, N = 1, 12, 2, 8
    ks = jax.random.split(rng, 4)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, N)) for i in range(3))
    logw = -jnp.exp(0.3 * jax.random.normal(ks[3], (B, T, H, N)))
    u = 0.2 * jnp.ones((H, N))
    S0 = jnp.zeros((B, H, N, N))
    y_all, _ = wkv_chunked(r, k, v, logw, u, S0, chunk=4)
    S = S0
    ys = []
    for t in range(T):
        y_t, S = wkv_chunked(r[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                             logw[:, t:t+1], u, S, chunk=1)
        ys.append(y_t)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_all, atol=2e-4,
                               rtol=1e-3)


def test_moe_local_dispatch_matches_oracle(rng):
    """Row-local dispatch (§Perf optimization) is math-identical to the
    dense oracle when capacity is ample."""
    from repro.models.moe import init_moe, apply_moe, \
        apply_moe_dense_oracle
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    p = init_moe(cfg, rng)
    x = jax.random.normal(jax.random.fold_in(rng, 2), (3, 24, cfg.d_model))
    out, aux = apply_moe(cfg, p, x, local_dispatch=True)
    ref = apply_moe_dense_oracle(cfg, p, x)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)
