"""Survey §7: evolution-based vs backprop-based training — per-step
inter-worker communication bytes (the survey's central scaling argument
for ES/GA) and generation throughput."""
import jax
import jax.numpy as jnp

import repro.envs as envs
from benchmarks.common import time_fn, emit
from repro.core.evo import ES, DeepGA
from repro.core.networks import MLPPolicy


def run():
    rows = []
    env = envs.make("pendulum")
    pol = MLPPolicy.for_spec(env.spec, hidden=(32, 32))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        pol.init(jax.random.PRNGKey(0))))

    es = ES(pol, env, pop_size=32, max_steps=100)
    theta = es.init(jax.random.PRNGKey(0))
    step = jax.jit(es.step)
    us = time_fn(step, theta, jax.random.PRNGKey(1), warmup=1, iters=3)
    _, _, es_comm = step(theta, jax.random.PRNGKey(1))
    rows.append(("sec7/es_generation", round(us, 1),
                 f"comm_bytes={es_comm};pop=32"))

    cenv = envs.make("cartpole")
    cpol = MLPPolicy.for_spec(cenv.spec, hidden=(32, 32))
    ga = DeepGA(cpol, cenv, pop_size=32, max_steps=100)
    gstate = ga.init(jax.random.PRNGKey(0))
    gstep = jax.jit(ga.step)
    us = time_fn(gstep, gstate, jax.random.PRNGKey(1), warmup=1, iters=3)
    _, _, ga_comm = gstep(gstate, jax.random.PRNGKey(1))
    rows.append(("sec7/ga_generation", round(us, 1),
                 f"comm_bytes={ga_comm};pop=32;seed_chain_encoding"))

    # DSGD reference: one gradient exchange = 4 bytes * n_params / worker
    dsgd_comm = 4 * n_params
    rows.append(("sec7/dsgd_reference", None,
                 f"comm_bytes={dsgd_comm};n_params={n_params}"))
    rows.append(("sec7/es_comm_reduction", None,
                 f"x{dsgd_comm / int(es_comm):.0f}"))
    return emit(rows)
