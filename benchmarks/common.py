"""Benchmark helpers: timing + CSV emission + machine-readable JSON.

Every benchmark's `run()` returns `(name, us_per_call, derived)` rows;
`write_bench_json` serializes them into the repo-root `BENCH_*.json`
schema (`repro-bench/v1`) that tracks the perf trajectory across PRs:

    {"schema": "repro-bench/v1", "benchmark": <module>,
     "backend": "cpu"|"tpu"|..., "meta": {...},
     "rows": [{"name", "us_per_call", "derived"}, ...]}
"""
import json
import os
import time

import jax

SCHEMA = "repro-bench/v1"
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def time_fn(fn, *args, warmup=2, iters=10):
    """us per call of a jitted fn (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows):
    """Print `name,us_per_call,derived` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
    return rows


def write_bench_json(benchmark, rows, **meta):
    """Write repo-root BENCH_<benchmark>.json in the repro-bench/v1
    schema; returns the path."""
    doc = {"schema": SCHEMA, "benchmark": benchmark,
           "backend": jax.default_backend(), "meta": meta,
           "rows": [{"name": name,
                     "us_per_call": (round(us, 2)
                                     if us is not None else None),
                     "derived": derived}
                    for name, us, derived in rows]}
    path = os.path.join(REPO_ROOT, f"BENCH_{benchmark}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path
