"""Benchmark helpers: timing + CSV emission + machine-readable JSON.

Every benchmark's `run()` returns `(name, us_per_call, derived)` rows;
`write_bench_json` serializes them into the repo-root `BENCH_*.json`
schema (`repro-bench/v1`) that tracks the perf trajectory across PRs:

    {"schema": "repro-bench/v1", "benchmark": <module>,
     "backend": "cpu"|"tpu"|..., "meta": {...},
     "rows": [{"name", "us_per_call", "derived"}, ...]}
"""
import json
import os
import time

import jax

SCHEMA = "repro-bench/v1"
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def time_fn(fn, *args, warmup=2, iters=10):
    """us per call of a jitted fn (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows):
    """Print `name,us_per_call,derived` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
    return rows


_ROW_KEYS = {"name", "us_per_call", "derived"}


def validate_bench_json(doc):
    """Validate the repro-bench/v1 shape (top-level keys and row
    types), raising ValueError naming the offending key or row —
    tests/test_bench_schema.py runs this over every repo-root
    BENCH_*.json so the perf trajectory can't silently rot. Returns
    `doc` for chaining."""
    if not isinstance(doc, dict):
        raise ValueError(f"bench doc must be a JSON object, "
                         f"got {type(doc).__name__}")
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"key 'schema' must be {SCHEMA!r}, "
                         f"got {doc.get('schema')!r}")
    for key, typ in (("benchmark", str), ("backend", str),
                     ("meta", dict), ("rows", list)):
        if not isinstance(doc.get(key), typ):
            raise ValueError(
                f"key {key!r} must be {typ.__name__}, got "
                f"{type(doc.get(key)).__name__}: {doc.get(key)!r}")
    for i, row in enumerate(doc["rows"]):
        if not isinstance(row, dict):
            raise ValueError(f"rows[{i}] must be an object, got "
                             f"{type(row).__name__}")
        if set(row) != _ROW_KEYS:
            raise ValueError(f"rows[{i}] keys {sorted(row)} != "
                             f"{sorted(_ROW_KEYS)}")
        if not isinstance(row["name"], str):
            raise ValueError(f"rows[{i}]['name'] must be a string, "
                             f"got {row['name']!r}")
        if not (row["us_per_call"] is None
                or isinstance(row["us_per_call"], (int, float))):
            raise ValueError(f"rows[{i}]['us_per_call'] must be a "
                             f"number or null, got {row['us_per_call']!r}")
        if not isinstance(row["derived"], str):
            raise ValueError(f"rows[{i}]['derived'] must be a string, "
                             f"got {row['derived']!r}")
    return doc


def write_bench_json(benchmark, rows, out_dir=None, **meta):
    """Write BENCH_<benchmark>.json in the repro-bench/v1 schema to
    `out_dir` (repo root by default); returns the path. `meta.quick`
    is always stamped (defaulting to False) so
    tests/test_bench_schema.py can reject committed files produced by
    an incidental `--quick` regeneration — the committed trajectory
    must be full-mode runs. Tests that exercise bench-writing CLIs
    should pass a temp `out_dir` so the repo-root files only ever
    change on a deliberate regeneration."""
    meta.setdefault("quick", False)
    doc = {"schema": SCHEMA, "benchmark": benchmark,
           "backend": jax.default_backend(), "meta": meta,
           "rows": [{"name": name,
                     "us_per_call": (round(us, 2)
                                     if us is not None else None),
                     "derived": derived}
                    for name, us, derived in rows]}
    validate_bench_json(doc)  # never write a malformed trajectory file
    path = os.path.join(out_dir or REPO_ROOT, f"BENCH_{benchmark}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path
