"""Benchmark helpers: timing + CSV emission."""
import time

import jax


def time_fn(fn, *args, warmup=2, iters=10):
    """us per call of a jitted fn (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows):
    """Print `name,us_per_call,derived` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
    return rows
