"""Survey Table 1 (computing parallelism): environment-steps/second as
batch-simulation width scales — the single-machine-parallelism column of
the survey, realized as vmap width on one device."""
import jax
import jax.numpy as jnp

import repro.envs as envs
from benchmarks.common import time_fn, emit
from repro.core.networks import MLPPolicy
from repro.core.rollout import rollout


def run():
    env = envs.make("cartpole")
    pol = MLPPolicy.for_spec(env.spec, hidden=(32,))
    params = pol.init(jax.random.PRNGKey(0))
    T = 64
    rows = []
    for n in (1, 8, 64, 256, 1024):
        state = env.reset_batch(jax.random.PRNGKey(1), n)
        fn = jax.jit(lambda p, k, s: rollout(pol, p, env, k, s, T))
        us = time_fn(fn, params, jax.random.PRNGKey(2), state, iters=5)
        fps = n * T / (us / 1e6)
        rows.append((f"table1/batch_sim_width_{n}", round(us, 1),
                     f"fps={fps:.0f}"))
    return emit(rows)
