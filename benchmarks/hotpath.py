"""Pallas-fused learner hot path benchmark (tentpole PR 3).

Three measurements, written machine-readably to repo-root
BENCH_hotpath.json (repro-bench/v1 schema):

  1. advantages: the reverse-scan kernel (GAE + n-step returns) vs the
     lax.scan ref oracle;
  2. replay_sample: the fused Gumbel-top-k prioritized-sampling kernel
     vs its jnp ref AND the legacy categorical+gather path it replaces;
  3. zero-copy supersteps: the DQN Trainer superstep (replay_capacity
     >= 20k) with donate_argnums on vs off — walltime per superstep and
     peak live bytes from XLA's compiled memory analysis (argument +
     output + temp − donated-alias);
  4. attention (PR 8): the transformer-trunk policy's attention seam —
     a naive jnp full-softmax (materializes the (S, S) score matrix) vs
     the core/attention.py dispatcher's ref path vs the Pallas
     flash-attention kernel, all in the trunk's (B, S, KVH, G, D)
     grouped-query layout.

Off-TPU the Pallas kernels execute in interpret mode (meta records it)
— their timings track the trajectory, not peak speed; the donation and
legacy-vs-fused-ref comparisons are real on every backend.

Usage: python benchmarks/hotpath.py [--quick]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp


def _setup_path():
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), ".."))


if __package__ is None or __package__ == "":
    _setup_path()

from benchmarks.common import emit, time_fn, write_bench_json  # noqa: E402
from repro.kernels.common import interpret_mode  # noqa: E402


def _advantage_rows(quick):
    from repro.kernels.advantages import ops as aops
    from repro.kernels.advantages.ref import gae_ref, nstep_return_ref
    T, B = (16, 64) if quick else (64, 512)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    rew = jax.random.normal(ks[0], (T, B))
    val = jax.random.normal(ks[1], (T, B))
    dones = jax.random.uniform(ks[2], (T, B)) < 0.05
    boot = jax.random.normal(ks[3], (B,))
    shape = f"T={T};B={B}"
    interp = f"interpret={interpret_mode()}"
    rows = []
    for name, fn in (("gae_ref", jax.jit(gae_ref)),
                     ("gae_kernel", jax.jit(aops.gae)),
                     ("nstep_ref", jax.jit(nstep_return_ref)),
                     ("nstep_kernel", jax.jit(aops.nstep_return))):
        args = ((rew, val, dones, boot) if "gae" in name
                else (rew, dones, boot))
        us = time_fn(fn, *args, warmup=2, iters=3 if quick else 10)
        tag = shape + (";" + interp if "kernel" in name else "")
        rows.append((f"advantages/{name}", us, tag))
    return rows


def _replay_rows(quick):
    from repro.core.replay import PrioritizedReplay
    from repro.core.replay_sample import fused_prioritized_sample
    from repro.kernels.replay_sample.ops import prioritized_sample
    C, n = 20000, 64
    key = jax.random.PRNGKey(1)
    prio = jnp.abs(jax.random.normal(key, (C,))) + 0.01
    example = {"obs": jnp.zeros((4,)), "a": jnp.zeros((), jnp.int32)}
    iters = 3 if quick else 10

    def fill(rp):
        st = rp.init(example)
        st = rp.add_batch(st, jax.tree_util.tree_map(
            lambda a: jnp.zeros((C,) + a.shape, a.dtype), example))
        return dict(st, prio=prio)

    legacy = PrioritizedReplay(C)
    st = fill(legacy)
    f_legacy = jax.jit(lambda s, k: legacy.sample(s, k, n)[1:])
    us_legacy = time_fn(f_legacy, st, key, warmup=2, iters=iters)

    # the production fused path, apples-to-apples with the legacy row:
    # includes the per-call (C,) Gumbel generation
    fused = PrioritizedReplay(C, fused=True)
    f_fused = jax.jit(lambda s, k: fused.sample(s, k, n)[1:])
    us_fused = time_fn(f_fused, st, key, warmup=2, iters=iters)

    gum = jax.random.gumbel(key, (C,))
    f_ref = jax.jit(lambda p, s, g: fused_prioritized_sample(
        p, s, g, n, use_kernel=False))
    us_ref = time_fn(f_ref, prio, st["size"], gum, warmup=2, iters=iters)
    f_kern = jax.jit(lambda p, s, g: prioritized_sample(p, s, g, n))
    us_kern = time_fn(f_kern, prio, st["size"], gum, warmup=2,
                      iters=iters)
    shape = f"C={C};n={n}"
    return [
        ("replay_sample/legacy_categorical", us_legacy,
         shape + ";with_replacement;full_sample"),
        ("replay_sample/fused_sample", us_fused,
         f"{shape};gumbel_topk;full_sample;"
         f"speedup_vs_legacy=x{us_legacy / us_fused:.1f}"),
        ("replay_sample/fused_ref", us_ref,
         shape + ";gumbel_topk;bare_seam"),
        ("replay_sample/fused_kernel", us_kern,
         f"{shape};gumbel_topk;bare_seam;interpret={interpret_mode()}"),
    ]


def _naive_attention(qg, k, v, causal=True):
    """Full-softmax attention in the (B, S, KVH, G, D) grouped-query
    layout — the O(S^2)-memory baseline the flash kernel replaces."""
    B, S, KVH, G, D = qg.shape
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.astype(qg.dtype)


def _attention_rows(quick):
    from repro.core.attention import attention
    from repro.kernels.flash_attention.ops import flash_attention
    B, S, KVH, G, D = (2, 128, 2, 2, 32) if quick else (2, 256, 2, 2, 64)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    qg = jax.random.normal(ks[0], (B, S, KVH, G, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    iters = 3 if quick else 10
    shape = f"B={B};S={S};KVH={KVH};G={G};D={D};causal"
    f_naive = jax.jit(_naive_attention)
    f_ref = jax.jit(lambda q, kk, vv: attention(q, kk, vv, causal=True,
                                                use_kernel=False))
    f_kern = jax.jit(lambda q, kk, vv: flash_attention(q, kk, vv,
                                                       causal=True))
    us_naive = time_fn(f_naive, qg, k, v, warmup=2, iters=iters)
    us_ref = time_fn(f_ref, qg, k, v, warmup=2, iters=iters)
    us_kern = time_fn(f_kern, qg, k, v, warmup=2, iters=iters)
    return [
        ("attention/naive_jnp", us_naive, shape + ";full_softmax"),
        ("attention/flash_ref", us_ref, shape + ";dispatcher_ref"),
        ("attention/flash_kernel", us_kern,
         f"{shape};interpret={interpret_mode()}"),
    ]


def _bytes(trainer, k, donate):
    ma = trainer.lower(k, donate=donate).compile().memory_analysis()
    alias = ma.alias_size_in_bytes
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - alias)
    return live, alias


def _superstep_rows(quick):
    import repro.envs as envs
    from repro.core.trainer import Trainer, TrainerConfig
    K = 4 if quick else 8
    reps = 2 if quick else 6
    cap = 20000
    env = envs.make("cartpole")
    results = {}
    for donate in (False, True):
        cfg = TrainerConfig(algo="dqn", iters=K, superstep=K, n_envs=8,
                            unroll=8, donate=donate, log_every=K,
                            algo_kwargs={"replay_capacity": cap,
                                         "warmup": 1, "hidden": (32,)})
        tr = Trainer(env, cfg)
        state, sim, delays = tr._init_all()
        step = tr._superstep(K)
        its = jnp.arange(K, dtype=jnp.int32)
        state, sim, m = step(state, sim, its, delays[:K])  # compile
        jax.block_until_ready(m)
        t0 = time.perf_counter()
        for _ in range(reps):
            state, sim, m = step(state, sim, its, delays[:K])
        jax.block_until_ready(m)
        wall = (time.perf_counter() - t0) / reps
        live, alias = _bytes(tr, K, donate)
        results[donate] = (wall, live, alias)
    (w0, l0, _), (w1, l1, a1) = results[False], results[True]
    return [
        ("superstep/dqn_donate_off", w0 / K * 1e6,
         f"wall_s={w0:.4f};K={K};replay_capacity={cap};live_bytes={l0}"),
        ("superstep/dqn_donate_on", w1 / K * 1e6,
         f"wall_s={w1:.4f};K={K};replay_capacity={cap};live_bytes={l1}"
         f";alias_bytes={a1}"),
        ("superstep/donation_walltime_speedup", None,
         f"x{w0 / w1:.2f}"),
        ("superstep/donation_bytes_saved", None,
         f"bytes={l0 - l1};pct={100.0 * (l0 - l1) / max(l0, 1):.1f}"),
    ]


def run(quick=False):
    rows = (_advantage_rows(quick) + _replay_rows(quick)
            + _attention_rows(quick) + _superstep_rows(quick))
    emit(rows)
    path = write_bench_json("hotpath", rows, quick=quick,
                            interpret_kernels=interpret_mode())
    print(f"# wrote {path}", file=sys.stderr)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes/reps (CI smoke)")
    run(quick=ap.parse_args().quick)


if __name__ == "__main__":
    main()
