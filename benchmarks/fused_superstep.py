"""Fused-superstep dispatch benchmark (unified Trainer tentpole):
K jitted iterations per host round-trip vs per-iteration dispatch.

The legacy drivers blocked on `float(loss)` every iteration; the Trainer
scans K iterations inside one program and reads metrics back once per
superstep. Both paths are numerically identical (tests/test_trainer.py),
so the delta is pure dispatch + host-sync overhead. Timed on the second
`fit` call — compilation is cached in the Trainer — so the comparison is
steady-state."""
import time

from benchmarks.common import emit
from repro.core.distribution import DistPlan
from repro.core.trainer import Trainer, TrainerConfig
import repro.envs as envs


def _timed_fit(trainer, fused):
    trainer.fit(fused=fused)            # warm the jit cache
    t0 = time.perf_counter()
    trainer.fit(fused=fused)
    return time.perf_counter() - t0


def run():
    env = envs.make("cartpole")
    cfg = TrainerConfig(algo="impala", iters=96, superstep=16, n_envs=16,
                        unroll=16, plan=DistPlan.flat(), log_every=96)
    trainer = Trainer(env, cfg)
    fused_s = _timed_fit(trainer, fused=True)
    unfused_s = _timed_fit(trainer, fused=False)
    return emit([
        ("superstep/fused", fused_s / cfg.iters * 1e6,
         f"wall_s={fused_s:.3f};iters={cfg.iters};K={cfg.superstep}"),
        ("superstep/unfused", unfused_s / cfg.iters * 1e6,
         f"wall_s={unfused_s:.3f};iters={cfg.iters};K=1"),
        ("superstep/speedup", None,
         f"fused_vs_unfused={unfused_s / fused_s:.2f}x"),
    ])
