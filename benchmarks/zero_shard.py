"""ZeRO-style learner-state sharding benchmark (tentpole PR 5).

Measures the per-device memory footprint of the learner state under a
replicated DistPlan vs a `shard`-role axis (ZeRO-2: optimizer state
partitioned 1/N per device, gradients reduce-scattered, params
all-gathered before the next rollout):

  1. exact pytree accounting: per-device bytes of `TrainState.params`
     and `opt_state` straight off the initialized, mesh-laid-out state
     (replicated plans carry the full adamw m/v per device; sharded
     plans carry one 1/N flattened chunk);
  2. XLA ground truth: live bytes (argument + output + temp − donated
     alias) of the compiled superstep from
     `Trainer.lower(k).compile().memory_analysis()`;
  3. walltime per superstep for both plans (the all-gather cost the
     memory saving buys).

The headline row `zero2/opt_state_shrink` pins the acceptance claim:
per-device opt_state bytes shrink ~1/shard_size (within flatten-and-pad
padding) for the sharded plan. Always writes repo-root BENCH_zero.json
(repro-bench/v1) — the perf trajectory for learner sharding starts
there.

Usage: python benchmarks/zero_shard.py [--quick]
"""
import argparse
import os
import sys
import time

N_DEVICES = 4  # replicated workers=4 vs workers=2 x shard=2

# the plans below need fake host devices; force them before jax loads
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count="
            f"{N_DEVICES}").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def _setup_path():
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), ".."))


if __package__ is None or __package__ == "":
    _setup_path()

from benchmarks.common import emit, write_bench_json  # noqa: E402


def _per_device_bytes(tree, n_devices):
    """Exact per-device bytes of a mesh-laid-out pytree (every leaf
    carries one leading dim per mesh axis, so total/n_devices is one
    device's slice)."""
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(tree)
               ) // n_devices


def _live_bytes(trainer, k):
    ma = trainer.lower(k).compile().memory_analysis()
    return (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)


def _measure(env, plan, label, quick, hidden):
    from repro.core.trainer import Trainer, TrainerConfig
    K = 2 if quick else 4
    reps = 2 if quick else 5
    cfg = TrainerConfig(algo="impala", iters=K, superstep=K, n_envs=8,
                        unroll=8, plan=plan, log_every=K,
                        algo_kwargs={"hidden": hidden})
    tr = Trainer(env, cfg)
    state, sim, delays = tr._init_all()
    nd = plan.n_devices
    params_b = _per_device_bytes(state.params, nd)
    opt_b = _per_device_bytes(state.opt_state, nd)
    step = tr._superstep(K)
    its = jnp.arange(K, dtype=jnp.int32)
    state, sim, m = step(state, sim, its, delays[:K])  # compile
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    for _ in range(reps):
        state, sim, m = step(state, sim, its, delays[:K])
    jax.block_until_ready(m)
    wall = (time.perf_counter() - t0) / reps
    live = _live_bytes(tr, K)
    return {"label": label, "plan": plan.describe(),
            "params_b": params_b, "opt_b": opt_b, "wall": wall,
            "live": live, "K": K, "partition": tr.partition}


def run(quick=False):
    import repro.envs as envs
    from repro.core.distribution import DistPlan

    hidden = (64, 64) if quick else (256, 256)
    env = envs.make("cartpole")
    rep = _measure(env, DistPlan.flat(N_DEVICES), "replicated", quick,
                   hidden)
    shd = _measure(env, DistPlan.zero(N_DEVICES // 2, 2), "zero2", quick,
                   hidden)
    n_shards = shd["partition"]["n_shards"]
    pad_b = 4 * (shd["partition"]["padded"] - shd["partition"]["size"])
    rows = []
    for r in (rep, shd):
        rows.append((
            f"zero_shard/{r['label']}", r["wall"] / r["K"] * 1e6,
            f"plan={r['plan']};params_per_device_bytes={r['params_b']};"
            f"opt_state_per_device_bytes={r['opt_b']};"
            f"state_per_device_bytes={r['params_b'] + r['opt_b']};"
            f"xla_live_bytes={r['live']};K={r['K']}"))
    shrink = shd["opt_b"] / max(rep["opt_b"], 1)
    total_shrink = ((shd["params_b"] + shd["opt_b"])
                    / max(rep["params_b"] + rep["opt_b"], 1))
    rows.append((
        "zero2/opt_state_shrink", None,
        f"ratio={shrink:.4f};ideal=1/{n_shards};padding_bytes={pad_b};"
        f"params_plus_opt_ratio={total_shrink:.4f};"
        f"xla_live_saved_bytes={rep['live'] - shd['live']}"))
    emit(rows)
    path = write_bench_json("zero", rows, quick=quick,
                            n_devices=N_DEVICES,
                            partition=shd["partition"])
    print(f"# wrote {path}", file=sys.stderr)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes/reps (CI smoke)")
    run(quick=ap.parse_args().quick)


if __name__ == "__main__":
    main()
