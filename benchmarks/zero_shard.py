"""ZeRO-style learner-state sharding benchmark (tentpole PR 5 + PR 8).

Measures the per-device memory footprint of the learner state under a
replicated DistPlan vs a `shard`-role axis (ZeRO-2: optimizer state
partitioned 1/N per device, gradients reduce-scattered, params
all-gathered before the next rollout) and vs a `zero3`-role axis
(full ZeRO-3: params stored sharded too, all-gathered per use inside
learner_step/actor_policy) on the transformer policy trunk:

  1. exact pytree accounting: per-device bytes of `TrainState.params`
     and `opt_state` straight off the initialized, mesh-laid-out state
     (replicated plans carry the full adamw m/v per device; sharded
     plans carry one 1/N flattened chunk);
  2. XLA ground truth from `Trainer.lower(k).compile()
     .memory_analysis()`: argument bytes (the persistent state the
     program carries between supersteps — where learner-state sharding
     shows up directly) and live bytes (argument + output + temp −
     donated alias; for ZeRO-3 the transient gather-per-use buffers
     land in temp, offsetting the argument saving at small shard
     counts);
  3. walltime per superstep for both plans (the all-gather cost the
     memory saving buys).

The headline row `zero2/opt_state_shrink` pins PR 5's acceptance claim:
per-device opt_state bytes shrink ~1/shard_size (within flatten-and-pad
padding) for the sharded plan. `zero3/param_state_shrink` pins PR 8's:
per-device params+opt_state bytes ratio <= 0.67 vs replicated at 2
shards on the transformer trunk (adamw: 3P replicated -> 1.5P at n=2,
ideal 0.5), with XLA argument bytes corroborating the persistent-state
shrink. `zero3_layerwise/peak_live_shrink` pins PR 10's: with the
per-block partition list (one flatten-and-pad entry per trunk
superblock + the non-block remainder, gathered → run → dropped one at
a time inside `_run_seq`'s unrolled loop), XLA peak LIVE bytes at 2
shards drop strictly below the replicated plan — the whole-vector
gather's full-size temps erased the saving at any N, so this row is
the first genuinely memory-bound training regime the sharding
subsystem delivers. Always writes repo-root
BENCH_zero.json (repro-bench/v1) — the perf trajectory for learner
sharding starts there.

Usage: python benchmarks/zero_shard.py [--quick]
"""
import argparse
import os
import sys
import time

N_DEVICES = 4  # replicated workers=4 vs workers=2 x shard=2

# the plans below need fake host devices; force them before jax loads
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count="
            f"{N_DEVICES}").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def _setup_path():
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), ".."))


if __package__ is None or __package__ == "":
    _setup_path()

from benchmarks.common import emit, write_bench_json  # noqa: E402


def _per_device_bytes(tree, n_devices):
    """Exact per-device bytes of a mesh-laid-out pytree (every leaf
    carries one leading dim per mesh axis, so total/n_devices is one
    device's slice)."""
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(tree)
               ) // n_devices


def _xla_bytes(trainer, k):
    ma = trainer.lower(k).compile().memory_analysis()
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return live, ma.argument_size_in_bytes


def _measure(env, plan, label, quick, hidden, algo_kwargs=None):
    from repro.core.trainer import Trainer, TrainerConfig
    K = 2 if quick else 4
    reps = 2 if quick else 5
    cfg = TrainerConfig(algo="impala", iters=K, superstep=K, n_envs=8,
                        unroll=8, plan=plan, log_every=K,
                        algo_kwargs=algo_kwargs if algo_kwargs is not None
                        else {"hidden": hidden})
    tr = Trainer(env, cfg)
    state, sim, delays = tr._init_all()
    nd = plan.n_devices
    params_b = _per_device_bytes(state.params, nd)
    opt_b = _per_device_bytes(state.opt_state, nd)
    step = tr._superstep(K)
    its = jnp.arange(K, dtype=jnp.int32)
    state, sim, m = step(state, sim, its, delays[:K])  # compile
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    for _ in range(reps):
        state, sim, m = step(state, sim, its, delays[:K])
    jax.block_until_ready(m)
    wall = (time.perf_counter() - t0) / reps
    live, arg_b = _xla_bytes(tr, K)
    return {"label": label, "plan": plan.describe(),
            "params_b": params_b, "opt_b": opt_b, "wall": wall,
            "live": live, "arg_b": arg_b, "K": K,
            "partition": tr.partition}


def run(quick=False):
    import repro.envs as envs
    from repro.core.distribution import DistPlan

    hidden = (64, 64) if quick else (256, 256)
    env = envs.make("cartpole")
    rep = _measure(env, DistPlan.flat(N_DEVICES), "replicated", quick,
                   hidden)
    shd = _measure(env, DistPlan.zero(N_DEVICES // 2, 2), "zero2", quick,
                   hidden)
    n_shards = shd["partition"]["n_shards"]
    pad_b = 4 * (shd["partition"]["padded"] - shd["partition"]["size"])
    rows = []
    for r in (rep, shd):
        rows.append((
            f"zero_shard/{r['label']}", r["wall"] / r["K"] * 1e6,
            f"plan={r['plan']};params_per_device_bytes={r['params_b']};"
            f"opt_state_per_device_bytes={r['opt_b']};"
            f"state_per_device_bytes={r['params_b'] + r['opt_b']};"
            f"xla_live_bytes={r['live']};K={r['K']}"))
    shrink = shd["opt_b"] / max(rep["opt_b"], 1)
    total_shrink = ((shd["params_b"] + shd["opt_b"])
                    / max(rep["params_b"] + rep["opt_b"], 1))
    rows.append((
        "zero2/opt_state_shrink", None,
        f"ratio={shrink:.4f};ideal=1/{n_shards};padding_bytes={pad_b};"
        f"params_plus_opt_ratio={total_shrink:.4f};"
        f"xla_live_saved_bytes={rep['live'] - shd['live']}"))

    # ZeRO-3 on the transformer trunk (PR 8): params stored sharded too
    tk = {"policy": "trunk", "trunk_kwargs": {"reduced": quick}}
    rep3 = _measure(env, DistPlan.flat(N_DEVICES), "replicated_trunk",
                    quick, None, algo_kwargs=tk)
    z3 = _measure(env, DistPlan.zero3(N_DEVICES // 2, 2), "zero3_trunk",
                  quick, None, algo_kwargs=tk)
    for r in (rep3, z3):
        rows.append((
            f"zero_shard/{r['label']}", r["wall"] / r["K"] * 1e6,
            f"plan={r['plan']};params_per_device_bytes={r['params_b']};"
            f"opt_state_per_device_bytes={r['opt_b']};"
            f"state_per_device_bytes={r['params_b'] + r['opt_b']};"
            f"xla_live_bytes={r['live']};xla_arg_bytes={r['arg_b']};"
            f"K={r['K']}"))
    n3 = z3["partition"]["n_shards"]
    pad3 = 4 * (z3["partition"]["padded"] - z3["partition"]["size"])
    ratio3 = ((z3["params_b"] + z3["opt_b"])
              / max(rep3["params_b"] + rep3["opt_b"], 1))
    rows.append((
        "zero3/param_state_shrink", None,
        f"ratio={ratio3:.4f};threshold=0.67;ideal=0.5;"
        f"params_ratio={z3['params_b'] / max(rep3['params_b'], 1):.4f};"
        f"opt_ratio={z3['opt_b'] / max(rep3['opt_b'], 1):.4f};"
        f"n_shards={n3};padding_bytes={pad3};"
        f"xla_arg_saved_bytes={rep3['arg_b'] - z3['arg_b']};"
        f"xla_live_saved_bytes={rep3['live'] - z3['live']}"))
    live_ratio = z3["live"] / max(rep3["live"], 1)
    rows.append((
        "zero3_layerwise/peak_live_shrink", None,
        f"live_ratio={live_ratio:.4f};threshold=0.95;"
        f"xla_live_bytes_replicated={rep3['live']};"
        f"xla_live_bytes_zero3={z3['live']};"
        f"xla_live_saved_bytes={rep3['live'] - z3['live']};"
        f"entries={z3['partition']['entries']};n_shards={n3}"))
    emit(rows)
    path = write_bench_json("zero", rows, quick=quick,
                            n_devices=N_DEVICES,
                            partition=shd["partition"],
                            partition_zero3=z3["partition"])
    print(f"# wrote {path}", file=sys.stderr)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes/reps (CI smoke)")
    run(quick=ap.parse_args().quick)


if __name__ == "__main__":
    main()
