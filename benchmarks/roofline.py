"""Roofline table: read experiments/dryrun/*.json and render the
per-(arch × shape × mesh) three-term analysis (§Roofline deliverable)."""
import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


ICI_BW = 50e9


def load_records(tag=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag is not None and r.get("tag", "") != tag:
            continue
        if r.get("status") == "ok":
            _add_wire_terms(r)
        recs.append(r)
    return recs


def _add_wire_terms(r):
    """Bytes-on-wire collective term (ring factors per op kind), from
    the stored per-kind breakdowns: corrected = top + (R-1) x probe."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    from repro.launch.hlo_analysis import wire_bytes
    top = r.get("collective_bytes", {})
    probe = r.get("collective_probe_bytes", {})
    reps = max(r.get("stack_repeats", 0) - 1, 0)
    kinds = {k: top.get(k, 0) + reps * probe.get(k, 0)
             for k in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute")}
    r["collective_wire_bytes"] = wire_bytes(kinds)
    r["collective_wire_term_s"] = r["collective_wire_bytes"] / ICI_BW


def render_markdown(recs, hw_note=True):
    lines = []
    if hw_note:
        lines.append("Hardware: TPU v5e — 197 TF/s bf16, 819 GB/s HBM, "
                     "50 GB/s/link ICI. Terms in seconds per step, "
                     "per chip.")
    lines.append("| arch | shape | mesh | compute_s | memory_s | "
                 "collective_s | bottleneck | useful_flops | note |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | — | — | {r['reason']} |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERR | | | | | {r.get('error', '')[:60]} |")
            continue
        uf = r.get("useful_flops_ratio")
        wire = r.get("collective_wire_term_s", r["collective_term_s"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_term_s']:.3e} | {r['memory_term_s']:.3e} | "
            f"{wire:.3e} | {r['bottleneck']} | "
            f"{uf:.2f} | compile={r.get('compile_s')}s |")
    return "\n".join(lines)


def run():
    recs = [r for r in load_records() if r.get("tag", "") == ""]
    rows = []
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    n_err = len(recs) - n_ok - n_skip
    rows.append(("roofline/records", None,
                 f"ok={n_ok};skipped={n_skip};error={n_err}"))
    for r in recs:
        if r["status"] != "ok":
            continue
        rows.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                     None,
                     f"compute={r['compute_term_s']:.3e};"
                     f"memory={r['memory_term_s']:.3e};"
                     f"collective={r['collective_term_s']:.3e};"
                     f"bottleneck={r['bottleneck']}"))
    return emit(rows)


if __name__ == "__main__":
    print(render_markdown(load_records()))
