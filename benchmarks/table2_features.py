"""Survey Table 2: this framework's row in the libraries/platforms
comparison (criteria: baseline algorithms, environment integration,
parallel & distributed features)."""
from benchmarks.common import emit


def run():
    rows = [
        ("table2/baseline_algorithms", None,
         "DQN(+double+prioritized);PPO;IMPALA(V-trace);A3C;ES;DeepGA;ERL"),
        ("table2/environments", None,
         "CartPole;Pendulum;GridWorld;host-pipeline wrapper;"
         "LM-as-actor (10 assigned architectures)"),
        ("table2/topologies", None, "parameter-server;allreduce;gossip"),
        ("table2/synchronization", None,
         "BSP;ASP;SSP(bounded staleness);V-trace off-policy correction"),
        ("table2/parallel_features", None,
         "zero-copy batch simulation (vmap+scan);pjit/shard_map "
         "(pod,data,model) mesh;ZeRO-3 FSDP;expert parallelism;"
         "Pallas TPU kernels (flash-attn, wkv6, gmm, vtrace)"),
        ("table2/scale_proven", None,
         "512-chip multi-pod dry-run; 40 (arch x shape) baselines"),
    ]
    return emit(rows)
