"""Sharded replay service benchmark (tentpole PR 9).

Measures what a `replay`-role DistPlan axis buys and costs for DQN's
prioritized replay (survey §3: Gorila's Replay Memory as its own
distributed component):

  1. exact pytree accounting: per-device bytes of
     `TrainState.extra["replay"]` straight off the initialized,
     mesh-laid-out state — a flat plan carries the FULL capacity-sized
     buffer per device, a replay axis of size R carries one 1/R chunk
     per member;
  2. XLA ground truth from `Trainer.lower(k).compile()
     .memory_analysis()`: argument bytes (persistent between-superstep
     state, where the buffer shrink shows up) and live bytes (the
     sample path all-gathers the (capacity,) priorities per use, a
     transient cost much smaller than the store rows saved);
  3. walltime per superstep for both plans (the merge/all-gather cost
     the capacity scaling buys) plus a per-sample microbench of the
     flat fused Gumbel-top-k draw vs the sharded per-shard-top-k +
     global-merge draw at equal global capacity.

The headline row `replay/replay_bytes_shrink` pins the acceptance
claim: per-device replay bytes ratio <= 0.67 vs the replicated plan at
2 shards (ideal 1/2 — ptr/size scalars and the priority vector are the
only non-store bytes). The two plans are bitwise-identical in training
history (tests/test_replay_service.py pins that); this file records
the memory/latency trade. Always writes repo-root BENCH_replay.json
(repro-bench/v1).

Usage: python benchmarks/replay_shard.py [--quick]
"""
import argparse
import os
import sys
import time

N_DEVICES = 4  # flat workers=2 baseline vs workers=2 x replay=2

# the plans below need fake host devices; force them before jax loads
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count="
            f"{N_DEVICES}").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def _setup_path():
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), ".."))


if __package__ is None or __package__ == "":
    _setup_path()

from benchmarks.common import emit, time_fn, write_bench_json  # noqa: E402


def _per_device_bytes(tree, n_devices):
    """Exact per-device bytes of a mesh-laid-out pytree (every leaf
    carries one leading dim per mesh axis, so total/n_devices is one
    device's slice)."""
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(tree)
               ) // n_devices


def _xla_bytes(trainer, k):
    ma = trainer.lower(k).compile().memory_analysis()
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return live, ma.argument_size_in_bytes


def _measure(env, plan, label, quick, capacity):
    from repro.core.trainer import Trainer, TrainerConfig
    K = 2 if quick else 4
    reps = 2 if quick else 5
    cfg = TrainerConfig(algo="dqn", iters=K, superstep=K, n_envs=8,
                        unroll=8, plan=plan, log_every=K,
                        algo_kwargs={"hidden": (64, 64),
                                     "replay_capacity": capacity,
                                     "warmup": 1})
    tr = Trainer(env, cfg)
    state, sim, delays = tr._init_all()
    nd = plan.n_devices
    replay_b = _per_device_bytes(state.extra["replay"], nd)
    step = tr._superstep(K)
    its = jnp.arange(K, dtype=jnp.int32)
    state, sim, m = step(state, sim, its, delays[:K])  # compile
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    for _ in range(reps):
        state, sim, m = step(state, sim, its, delays[:K])
    jax.block_until_ready(m)
    wall = (time.perf_counter() - t0) / reps
    live, arg_b = _xla_bytes(tr, K)
    return {"label": label, "plan": plan.describe(),
            "replay_b": replay_b, "wall": wall, "live": live,
            "arg_b": arg_b, "K": K,
            "partition_replay": tr.partition_replay}


def _sample_latency(capacity, n_shards, batch, quick):
    """us per prioritized sample draw: flat fused Gumbel-top-k vs the
    sharded per-shard-top-k + all-gather merge at the same GLOBAL
    capacity (vmap stands in for the mesh axis — same collectives)."""
    from repro.core.replay import PrioritizedReplay
    from repro.core.replay_service import ShardedPrioritizedReplay

    key = jax.random.key(0)
    example = {"obs": jnp.zeros((4,)), "action": jnp.zeros((), jnp.int32),
               "reward": jnp.zeros(()), "next_obs": jnp.zeros((4,)),
               "done": jnp.zeros((), bool)}
    fill = jax.tree_util.tree_map(
        lambda a: jnp.ones((capacity,) + a.shape, a.dtype), example)
    prio = jax.random.uniform(key, (capacity,)) + 0.1

    flat = PrioritizedReplay(capacity, fused=True)
    fstate = dict(flat.init(example), store=fill, prio=prio,
                  size=jnp.asarray(capacity, jnp.int32))
    f_us = time_fn(jax.jit(lambda k: flat.sample(fstate, k, batch)), key,
                   iters=5 if quick else 20)

    svc = ShardedPrioritizedReplay(capacity, "replay", n_shards)
    sstate = svc.shard_state(fstate)
    sampler = jax.jit(jax.vmap(
        lambda st, k: svc.sample(st, k, batch),
        in_axes=(0, None), axis_name="replay"))
    s_us = time_fn(lambda k: sampler(sstate, k), key,
                   iters=5 if quick else 20)
    return f_us, s_us


def run(quick=False):
    import repro.envs as envs
    from repro.core.distribution import DistPlan

    capacity = 2048 if quick else 16384
    env = envs.make("cartpole")
    rep = _measure(env, DistPlan.flat(2), "replicated", quick, capacity)
    shd = _measure(env, DistPlan.replay(2, 2), "sharded", quick, capacity)
    n_shards = shd["partition_replay"]["n_shards"]
    rows = []
    for r in (rep, shd):
        rows.append((
            f"replay_shard/{r['label']}", r["wall"] / r["K"] * 1e6,
            f"plan={r['plan']};replay_per_device_bytes={r['replay_b']};"
            f"capacity={capacity};xla_live_bytes={r['live']};"
            f"xla_arg_bytes={r['arg_b']};K={r['K']}"))
    shrink = shd["replay_b"] / max(rep["replay_b"], 1)
    rows.append((
        "replay/replay_bytes_shrink", None,
        f"ratio={shrink:.4f};threshold=0.67;ideal=1/{n_shards};"
        f"capacity={capacity};chunk={shd['partition_replay']['chunk']};"
        f"replicated_bytes={rep['replay_b']};"
        f"sharded_bytes={shd['replay_b']};"
        f"xla_arg_saved_bytes={rep['arg_b'] - shd['arg_b']}"))

    batch = 64
    f_us, s_us = _sample_latency(capacity, 2, batch, quick)
    rows.append(("replay_sample/flat_fused", f_us,
                 f"capacity={capacity};batch={batch}"))
    rows.append(("replay_sample/sharded_merge", s_us,
                 f"capacity={capacity};batch={batch};n_shards=2;"
                 f"overhead_ratio={s_us / max(f_us, 1e-9):.3f}"))
    emit(rows)
    path = write_bench_json("replay", rows, quick=quick,
                            n_devices=N_DEVICES, capacity=capacity,
                            partition_replay=shd["partition_replay"])
    print(f"# wrote {path}", file=sys.stderr)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes/reps (CI smoke)")
    run(quick=ap.parse_args().quick)


if __name__ == "__main__":
    main()
