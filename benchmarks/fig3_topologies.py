"""Survey Fig. 3 / §3: centralized (PS) vs decentralized (all-reduce) vs
gossip — HLO collective bytes per step + convergence, on an 8-worker
mesh (spawned in a subprocess so this process keeps one device)."""
import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import Mesh
    from repro.core.topology import make_distributed_step, replicate_for
    from repro.launch.hlo_analysis import collective_bytes
    from repro.optim import sgd
    mesh = Mesh(np.array(jax.devices()).reshape(8,), ("workers",))
    D = 4096  # param dim: makes collective sizes visible
    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 32, D))
    wt = jax.random.normal(jax.random.fold_in(key, 1), (D,)) / D ** 0.5
    y = jnp.einsum("wbd,d->wb", x, wt)
    p0 = {"w": jnp.zeros((D,))}
    opt = sgd(2e-4)  # lr ~ 1/D for the quadratic to contract
    out = {}
    for topo in ("allreduce", "ps", "gossip"):
        params = replicate_for(mesh, "workers", p0)
        ostate = replicate_for(mesh, "workers", opt.init(p0))
        step = make_distributed_step(loss, opt, topo, mesh)
        lowered = step.lower(params, ostate, {"x": x, "y": y})
        coll = collective_bytes(lowered.compile().as_text())
        for i in range(20):
            params, ostate, l = step(params, ostate, {"x": x, "y": y})
        out[topo] = {"collective_bytes": coll["total"],
                     "counts": coll["counts"],
                     "final_loss": float(l)}
    print("RESULT " + json.dumps(out))
""")


def run():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    if r.returncode != 0:
        return emit([("fig3/error", None, r.stderr[-300:])])
    res = json.loads([ln for ln in r.stdout.splitlines()
                      if ln.startswith("RESULT ")][-1][7:])
    rows = []
    for topo, d in res.items():
        rows.append((f"fig3/{topo}", None,
                     f"collective_bytes_per_step={d['collective_bytes']};"
                     f"final_loss={d['final_loss']:.5f}"))
    return emit(rows)
