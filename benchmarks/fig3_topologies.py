"""Survey Fig. 3 / §3: centralized (PS) vs decentralized (all-reduce) vs
gossip — driven through the unified Trainer as 1-D DistPlans, plus one
hierarchical 2-D plan (intra-host allreduce + inter-host gossip on a
(hosts=2, workers=4) mesh) showing what the Distribution Plan API buys:
an 8-worker IMPALA/CartPole superstep is lowered per plan and its HLO
collective bytes compared, then trained to check all of them converge.
Spawned in a subprocess so this process keeps one device.

Always writes repo-root BENCH_topologies.json (repro-bench/v1) so the
distribution perf trajectory records across PRs."""
import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit, write_bench_json

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig
    from repro.launch.hlo_analysis import collective_bytes
    import repro.envs as envs
    env = envs.make("cartpole")
    plans = {
        "allreduce": DistPlan.flat(8, collective="allreduce"),
        "ps": DistPlan.flat(8, collective="ps"),
        "gossip": DistPlan.flat(8, collective="gossip"),
        "hier2x4": DistPlan.grid(2, 4, inter="gossip",
                                 intra="allreduce"),
    }
    out = {}
    for name, plan in plans.items():
        cfg = TrainerConfig(algo="impala", iters=30, superstep=10,
                            n_envs=32, unroll=16, plan=plan,
                            log_every=10,
                            algo_kwargs={"hidden": (64, 64)})
        tr = Trainer(env, cfg)
        coll = collective_bytes(tr.lower().compile().as_text())
        _, hist = tr.fit()
        out[name] = {"plan": plan.describe(),
                     "collective_bytes": coll["total"],
                     "counts": coll["counts"],
                     "final_loss": hist[-1]["loss"],
                     "final_return": hist[-1]["episode_return"]}
    print("RESULT " + json.dumps(out))
""")


def run():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=1200)
    if r.returncode != 0:
        rows = emit([("fig3/error", None, r.stderr[-300:])])
        # still record the failure so BENCH_topologies.json never shows
        # a stale previous run as the current revision
        write_bench_json("topologies", rows)
        return rows
    res = json.loads([ln for ln in r.stdout.splitlines()
                      if ln.startswith("RESULT ")][-1][7:])
    rows = []
    for name, d in res.items():
        rows.append((f"fig3/{name}", None,
                     f"plan={d['plan']};"
                     f"collective_bytes_per_superstep="
                     f"{d['collective_bytes']};"
                     f"final_loss={d['final_loss']:.4f};"
                     f"final_return={d['final_return']:.1f}"))
    emit(rows)
    write_bench_json("topologies", rows)
    return rows
