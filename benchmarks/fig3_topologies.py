"""Survey Fig. 3 / §3: centralized (PS) vs decentralized (all-reduce) vs
gossip — now driven through the unified Trainer: an 8-worker IMPALA/
CartPole superstep is lowered per topology and its HLO collective bytes
compared, then trained to check all three converge. Spawned in a
subprocess so this process keeps one device."""
import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    from repro.core.trainer import Trainer, TrainerConfig
    from repro.launch.hlo_analysis import collective_bytes
    import repro.envs as envs
    env = envs.make("cartpole")
    out = {}
    for topo in ("allreduce", "ps", "gossip"):
        cfg = TrainerConfig(algo="impala", iters=30, superstep=10,
                            n_envs=32, unroll=16, n_workers=8,
                            topology=topo, log_every=10,
                            algo_kwargs={"hidden": (64, 64)})
        tr = Trainer(env, cfg)
        coll = collective_bytes(tr.lower().compile().as_text())
        _, hist = tr.fit()
        out[topo] = {"collective_bytes": coll["total"],
                     "counts": coll["counts"],
                     "final_loss": hist[-1]["loss"],
                     "final_return": hist[-1]["episode_return"]}
    print("RESULT " + json.dumps(out))
""")


def run():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    if r.returncode != 0:
        return emit([("fig3/error", None, r.stderr[-300:])])
    res = json.loads([ln for ln in r.stdout.splitlines()
                      if ln.startswith("RESULT ")][-1][7:])
    rows = []
    for topo, d in res.items():
        rows.append((f"fig3/{topo}", None,
                     f"collective_bytes_per_superstep="
                     f"{d['collective_bytes']};"
                     f"final_loss={d['final_loss']:.4f};"
                     f"final_return={d['final_return']:.1f}"))
    return emit(rows)
