"""Benchmark harness entry: one module per survey table/figure.
Prints ``name,us_per_call,derived`` CSV; with ``--json`` each module's
rows are also written to repo-root ``BENCH_<module>.json`` in the
repro-bench/v1 schema (same one benchmarks/hotpath.py uses), so every
benchmark contributes to the machine-readable perf trajectory.

Usage: python -m benchmarks.run [module] [--json]
"""
import sys

from benchmarks.common import write_bench_json


def main() -> None:
    from benchmarks import (table1_computing, fig3_topologies,
                            fig5_simulation, fig6_sync, fused_superstep,
                            hotpath, sec7_evolution, table2_features,
                            roofline)
    mods = [("table1_computing", table1_computing),
            ("fig3_topologies", fig3_topologies),
            ("fig5_simulation", fig5_simulation),
            ("fig6_sync", fig6_sync),
            ("fused_superstep", fused_superstep),
            ("hotpath", hotpath),
            ("sec7_evolution", sec7_evolution),
            ("table2_features", table2_features),
            ("roofline", roofline)]
    args = [a for a in sys.argv[1:]]
    json_mode = "--json" in args
    args = [a for a in args if a != "--json"]
    only = args[0] if args else None
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and only != name:
            continue
        try:
            rows = mod.run()
            if json_mode and rows:
                write_bench_json(name, rows)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,,{type(e).__name__}: {e}")


if __name__ == '__main__':
    main()
