"""Benchmark harness entry: one module per survey table/figure.
Prints ``name,us_per_call,derived`` CSV."""
import sys


def main() -> None:
    from benchmarks import (table1_computing, fig3_topologies,
                            fig5_simulation, fig6_sync, fused_superstep,
                            sec7_evolution, table2_features, roofline)
    mods = [("table1_computing", table1_computing),
            ("fig3_topologies", fig3_topologies),
            ("fig5_simulation", fig5_simulation),
            ("fig6_sync", fig6_sync),
            ("fused_superstep", fused_superstep),
            ("sec7_evolution", sec7_evolution),
            ("table2_features", table2_features),
            ("roofline", roofline)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and only != name:
            continue
        try:
            mod.run()
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,,{type(e).__name__}: {e}")


if __name__ == '__main__':
    main()
