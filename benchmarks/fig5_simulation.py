"""Survey Fig. 5: zero-copy on-device batch simulation vs the CPU↔device
copy pipeline (io_callback round-trip per step)."""
import jax
import jax.numpy as jnp

import repro.envs as envs
from benchmarks.common import time_fn, emit
from repro.core.networks import MLPPolicy
from repro.core.rollout import rollout
from repro.envs.host_env import HostPipelined


def run():
    n, T = 64, 32
    base = envs.make("cartpole")
    pol = MLPPolicy.for_spec(base.spec, hidden=(32,))
    params = pol.init(jax.random.PRNGKey(0))
    rows = []
    results = {}
    for name, env in (("zero_copy", base),
                      ("host_pipeline", HostPipelined(base))):
        state = env.reset_batch(jax.random.PRNGKey(1), n)
        fn = jax.jit(lambda p, k, s: rollout(pol, p, env, k, s, T))
        us = time_fn(fn, params, jax.random.PRNGKey(2), state,
                     warmup=1, iters=3)
        results[name] = us
        fps = n * T / (us / 1e6)
        rows.append((f"fig5/{name}", round(us, 1), f"fps={fps:.0f}"))
    speedup = results["host_pipeline"] / results["zero_copy"]
    rows.append(("fig5/zero_copy_speedup", None, f"x{speedup:.1f}"))
    return emit(rows)
