"""Actor-learner pipeline overlap benchmark (tentpole PR 6).

Measures what the decoupled producer/consumer split (repro.core.pipeline
+ Trainer ``pipeline=`` mode) buys over running the same rollout and
learner work serially, for ppo and dqn at queue depths 0/1/2:

  1. ``fused``: the fused superstep program (rollout -> learner_step
     inside one lax.scan, one dispatch per K iterations) — the PR 3
     reference path;
  2. ``serial``: the decoupled-but-UNpipelined actor-learner system —
     per iteration, one learner-consumer dispatch
     (``Trainer._consumer_program``) then one rollout-producer dispatch
     (``Trainer._producer_program``), host-synced after each: exactly
     what a Gorila-style split costs without overlap. Its rollout and
     learn halves are timed separately, so ``serial = roll + learn``
     by construction;
  3. ``pipelined``: the combined K-tick program — queue pop, rollout of
     iteration t+depth, push, learner update of iteration t, all in ONE
     dispatch with the two halves left independent for the XLA
     scheduler.

The headline per-cell claim (pinned for depth >= 1 in
tests/test_bench_schema.py) is ``pipelined < serial``:
dispatch/boundary overhead is gone and, where the host has cores to
spare, the producer subgraph executes concurrently with the consumer.

  overlap_fraction = (roll + learn - pipelined) / min(roll, learn)

i.e. the share of the cheaper phase's walltime that the pipeline hid
(0 = fully serial, 1 = the cheaper phase entirely disappeared into the
other's shadow; single-core hosts sit near the dispatch-overhead floor,
multi-core hosts add true concurrency on top). Depth 0 (bsp) is the
lockstep control: bitwise the fused path, so its row is the
queue-machinery-is-free check, not an overlap claim.

Always writes repo-root BENCH_pipeline.json (repro-bench/v1).

Usage: python benchmarks/pipeline_overlap.py [--quick]
"""
import argparse
import os
import sys
import time

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def _setup_path():
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), ".."))


if __package__ is None or __package__ == "":
    _setup_path()

from benchmarks.common import emit, write_bench_json  # noqa: E402

ALGOS = ("ppo", "dqn")
DEPTHS = (0, 1, 2)


def _make_trainer(algo, depth, k, n_envs, unroll):
    import repro.envs as envs
    from repro.core.distribution import DistPlan
    from repro.core.trainer import Trainer, TrainerConfig

    if depth == 0:
        plan = DistPlan.flat(1)  # bsp -> lockstep
    else:
        plan = DistPlan.flat(1, sync="ssp", staleness_bound=depth,
                             max_delay=depth)
    cfg = TrainerConfig(algo=algo, iters=k, superstep=k, n_envs=n_envs,
                        unroll=unroll, plan=plan, log_every=k,
                        pipeline=True)
    return Trainer(envs.make("cartpole"), cfg)


def _fresh(tr, depth):
    """(state, sim, queue) ready for one superstep: the queue pre-filled
    with the `depth` in-flight trajectories steady state holds."""
    state, sim, _ = tr._init_all()
    queue = tr._init_queue(state, sim)
    if depth:
        fill = tr._producer_program(depth)
        sim, queue = fill(state, sim, queue,
                          jnp.arange(depth, dtype=jnp.int32),
                          jnp.zeros((depth,), jnp.int32))
    jax.block_until_ready((sim, queue))
    return state, sim, queue


def _measure(algo, depth, k, n_envs, unroll, reps):
    tr = _make_trainer(algo, depth, k, n_envs, unroll)
    its_k = jnp.arange(k, dtype=jnp.int32)
    d_k = jnp.zeros((k,), jnp.int32)
    d_1 = jnp.zeros((1,), jnp.int32)
    fill1 = tr._producer_program(1)
    drain1 = tr._consumer_program(1)
    pipe = tr._pipeline_superstep(k)
    fused = tr._superstep(k)

    def one_it(i):
        return jnp.arange(i, i + 1, dtype=jnp.int32)

    def serial_superstep():
        """Decoupled-unpipelined K iterations: alternate consumer and
        producer dispatches (producer-first at depth 0 — lockstep has
        nothing queued to consume yet). Returns the separately-timed
        (roll, learn) walltimes."""
        s, si, q = _fresh(tr, depth)
        t_roll = t_learn = 0.0
        for i in range(k):
            if depth == 0:
                t0 = time.perf_counter()
                si, q = fill1(s, si, q, one_it(i), d_1)
                jax.block_until_ready(q)
                t_roll += time.perf_counter() - t0
            t0 = time.perf_counter()
            s, si, q, m = drain1(s, si, q, one_it(i))
            jax.block_until_ready(m)
            t_learn += time.perf_counter() - t0
            if depth:
                t0 = time.perf_counter()
                si, q = fill1(s, si, q, one_it(i + depth), d_1)
                jax.block_until_ready(q)
                t_roll += time.perf_counter() - t0
        return t_roll, t_learn

    def pipe_superstep():
        s, si, q = _fresh(tr, depth)
        t0 = time.perf_counter()
        out = pipe(s, si, q, its_k, d_k)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    def fused_superstep():
        s, si, delays = tr._init_all()
        t0 = time.perf_counter()
        out = fused(s, si, its_k, delays[:k])
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    serial_superstep(); pipe_superstep(); fused_superstep()  # compile
    rolls, learns, pipes, fuseds = [], [], [], []
    for _ in range(reps):
        r, l = serial_superstep()
        rolls.append(r)
        learns.append(l)
        pipes.append(pipe_superstep())
        fuseds.append(fused_superstep())
    t_roll, t_learn = min(rolls), min(learns)
    t_pipe, t_fused = min(pipes), min(fuseds)
    overlap = (t_roll + t_learn - t_pipe) / min(t_roll, t_learn)
    return {"algo": algo, "depth": depth,
            "capacity": tr.pipeline_capacity,
            "roll": t_roll, "learn": t_learn, "pipe": t_pipe,
            "fused": t_fused, "overlap": overlap}


def run(quick=False):
    k = 4 if quick else 8
    reps = 3 if quick else 6
    n_envs, unroll = 128, 16
    rows = []
    cells = []
    for algo in ALGOS:
        for depth in DEPTHS:
            c = _measure(algo, depth, k, n_envs, unroll, reps)
            cells.append(c)
            us = 1e6 / k
            rows.append((
                f"pipeline/{algo}_d{depth}", c["pipe"] * us,
                f"depth={depth};capacity={c['capacity']};"
                f"fused_us={c['fused'] * us:.1f};"
                f"roll_us={c['roll'] * us:.1f};"
                f"learn_us={c['learn'] * us:.1f};"
                f"serial_sum_us={(c['roll'] + c['learn']) * us:.1f};"
                f"pipe_us={c['pipe'] * us:.1f};"
                f"overlap_fraction={c['overlap']:.4f}"))
    # headline: every depth>=1 cell ran the pipelined superstep strictly
    # under its serial rollout+learn sum (overlap_fraction > 0)
    deep = [c for c in cells if c["depth"] >= 1]
    worst = min(c["overlap"] for c in deep)
    rows.append((
        "pipeline/overlap_claim", None,
        f"cells={len(deep)};"
        f"all_below_serial={all(c['overlap'] > 0 for c in deep)};"
        f"worst_overlap_fraction={worst:.4f}"))
    emit(rows)
    path = write_bench_json("pipeline", rows, quick=quick, k=k,
                            n_envs=n_envs, unroll=unroll,
                            algos=list(ALGOS), depths=list(DEPTHS))
    print(f"# wrote {path}", file=sys.stderr)
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes/reps (CI smoke)")
    run(quick=ap.parse_args().quick)


if __name__ == "__main__":
    main()
