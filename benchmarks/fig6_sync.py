"""Survey Fig. 6 + §6.2: synchronization mechanisms — convergence under
staleness (BSP/SSP/ASP) and the barrier-cost throughput model."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.sync import (SyncConfig, make_delays,
                             train_with_staleness, sync_cost_model)
from repro.optim import sgd


def run():
    key = jax.random.PRNGKey(0)
    T, W = 80, 8
    x = jax.random.normal(key, (T, W, 32, 8))
    w_true = jnp.linspace(-1, 1, 8)
    y = jnp.einsum("twbd,d->twb", x, w_true)
    loss = lambda p, b: jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
    p0 = {"w": jnp.zeros((8,))}
    rows = []
    for mech in ("bsp", "ssp", "asp"):
        cfg = SyncConfig(mech, W, max_delay=8, staleness_bound=2)
        d = make_delays(cfg, T, jax.random.PRNGKey(3))
        _, losses = train_with_staleness(loss, p0, sgd(0.3),
                                         {"x": x, "y": y}, d)
        wall = float(sync_cost_model(cfg, 1.0, 0.3, T,
                                     jax.random.PRNGKey(4)))
        rows.append((f"fig6/{mech}", None,
                     f"final_loss={float(losses[-5:].mean()):.5f};"
                     f"model_wall_s={wall:.1f};"
                     f"mean_staleness={float(d.mean()):.2f}"))
    return emit(rows)
