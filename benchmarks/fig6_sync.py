"""Survey Fig. 6 + §6.2: synchronization mechanisms — convergence under
staleness (BSP/SSP/ASP) and the barrier-cost throughput model.

Driven end-to-end through the unified Trainer: each mechanism is a 1-D
DistPlan whose sync discipline renders as a policy-lag schedule into the
actor ring of an *uncorrected* actor-critic (A3C) on CartPole — the
survey's qualitative claim is that staleness degrades convergence
(BSP >= SSP >= ASP) while the analytic cost model orders wall-time the
other way (ASP <= SSP <= BSP).

Always writes repo-root BENCH_sync.json (repro-bench/v1) so the
distribution perf trajectory records across PRs."""
import jax

from benchmarks.common import emit, write_bench_json
from repro.core.distribution import DistPlan
from repro.core.sync import SyncConfig, sync_cost_model
from repro.core.trainer import Trainer, TrainerConfig
import repro.envs as envs


def run():
    env = envs.make("cartpole")
    rows = []
    for mech in ("bsp", "ssp", "asp"):
        plan = DistPlan.flat(1, sync=mech, max_delay=8,
                             staleness_bound=2)
        cfg = TrainerConfig(algo="a3c", iters=60, superstep=10,
                            n_envs=16, unroll=16, plan=plan,
                            seed=0, log_every=60)
        _, hist = Trainer(env, cfg).fit()
        scfg = SyncConfig(mech, 8, max_delay=8, staleness_bound=2)
        wall = float(sync_cost_model(scfg, 1.0, 0.3, 60,
                                     jax.random.PRNGKey(4)))
        rows.append((f"fig6/{mech}", None,
                     f"plan={plan.describe()};"
                     f"final_return={hist[-1]['episode_return']:.1f};"
                     f"final_loss={hist[-1]['loss']:.4f};"
                     f"model_wall_s={wall:.1f};"
                     f"ring_size={cfg.ring_size}"))
    emit(rows)
    write_bench_json("sync", rows)
    return rows
