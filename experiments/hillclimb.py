"""Perf hillclimb driver (§Perf): re-run selected (arch × shape) pairs
with candidate optimizations and record tagged dry-run JSONs next to the
baseline for before/after comparison.

  PYTHONPATH=src python experiments/hillclimb.py --pair gemma3-1b:train_4k \
      --policy attn_heads_only --tag hc1
"""
import argparse
import json
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, help="arch:shape")
    ap.add_argument("--policy", default="baseline")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--fsdp", action="store_true", default=None)
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--remat", default=None, choices=("on", "off"))
    ap.add_argument("--moe-local", action="store_true")
    ap.add_argument("--act-shard", action="store_true",
                    help="with_sharding_constraint on the scan carry")
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--mesh-shape", default=None)
    args = ap.parse_args()

    from repro.launch.dryrun import dryrun_one
    from repro.models.model import ModelOpts

    arch, shape = args.pair.split(":")
    opts = None
    if args.remat is not None or args.moe_local or args.act_shard:
        axes = ("data",) if args.act_shard else ()
        opts = ModelOpts(dtype=args.dtype,
                         remat=(args.remat or "on") == "on",
                         moe_local_dispatch=args.moe_local,
                         act_batch_axes=axes)
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split(","))
                  if args.mesh_shape else None)
    rec = dryrun_one(arch, shape, param_dtype=args.param_dtype,
                     fsdp=args.fsdp, model_opts=opts, tag=args.tag,
                     policy=args.policy, mesh_shape=mesh_shape)
    keys = ("status", "compile_s", "compute_term_s", "memory_term_s",
            "collective_term_s", "bottleneck", "collective_bytes_corrected",
            "error")
    print(json.dumps({k: rec.get(k) for k in keys}, indent=1))


if __name__ == "__main__":
    main()
